//! Line-delimited-JSON TCP front-end + client.
//!
//! Protocol: one JSON object per line.
//!   → {"query": "why is coffee good for health?"}
//!   ← {"text": "...", "pathway": "tweak_hit", "similarity": 0.83,
//!      "latency_us": 1234}
//!   → {"stats": true}   ← {"requests": 10, "latency_table": "...",
//!      "stages": [{"stage": "decode", "pathway": "miss", ...}], ...}
//!   → {"admin": "snapshot"}
//!   ← {"snapshot": true, "generation": 3, "entries": 120}
//!   → {"admin": "trace", "n": 4}
//!   ← {"traces": [{"id": 7, "pathway": "tweak_hit", "spans": [...]}, ...],
//!      "slow": [...], "finished": 42, "dropped": 0}
//!
//! The server accepts any number of concurrent connections; each connection
//! thread forwards to the shared `EngineHandle` (the engine thread owns the
//! PJRT client and does the batching). Connection reads carry a short
//! timeout so idle connections observe the stop flag instead of pinning
//! their thread in a blocking read forever.
//!
//! The accept loop itself runs BLOCKING: the pre-PR-5 loop used nonblocking
//! `accept` + a 5 ms sleep poll, which quantized every cold connect by up
//! to 5 ms of added latency. Connections are now accepted the instant they
//! arrive; shutdown wakes the blocked `accept` with a self-connect
//! ([`Shutdown::signal`]).
//!
//! Beside the TCP listener, [`HttpServer`] exposes an OpenAI-compatible
//! `POST /v1/chat/completions` endpoint (`[server] http_port`, 0 = off).
//! With `"stream": true` it replies as Server-Sent Events: one
//! `chat.completion.chunk` per token delta, a final chunk carrying
//! `finish_reason`, `usage`, and a `"tweakllm"` extension object
//! (`pathway`, `similarity`, `trace_id`), then `data: [DONE]`. Empty
//! liveness probes from the engine become SSE comment lines, so a closed
//! client socket surfaces as a write error and cancels the session.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{EngineHandle, Pathway, ReadMode, RoutedResponse, StreamEvent};
use crate::trace::StageSummary;
use crate::util::Json;

/// Extra fields merged into the `{"admin": "health"}` / `GET /healthz`
/// reply. Cluster roles (owner shipping a WAL, replica applying one, the
/// router itself) attach one to report replication lag, shard-map epoch,
/// and role alongside the engine's breaker states.
pub type HealthExtra = Arc<dyn Fn() -> Json + Send + Sync>;

pub fn pathway_str(p: Pathway) -> &'static str {
    match p {
        Pathway::ExactHit => "exact_hit",
        Pathway::TweakHit => "tweak_hit",
        Pathway::DegradedHit => "degraded_hit",
        Pathway::Miss => "miss",
    }
}

pub struct Server {
    listener: TcpListener,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
    health: Option<HealthExtra>,
}

/// Stop handle for a serving [`Server`]: raises the stop flag AND wakes the
/// blocked `accept` with a self-connect, so shutdown is immediate without
/// the accept loop ever polling.
#[derive(Clone)]
pub struct Shutdown {
    stop: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl Shutdown {
    pub(crate) fn new(stop: Arc<AtomicBool>, addr: std::net::SocketAddr) -> Shutdown {
        Shutdown { stop, addr }
    }

    /// Ask the server to stop serving. Idempotent; returns once the wake
    /// connection has been issued (the serve loop exits on observing it).
    pub fn signal(&self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a self-connect. A wildcard bind
        // address (0.0.0.0 / ::) is not portably connectable — rewrite it
        // to the matching loopback. A failure (listener already closed)
        // means the loop is past accepting — nothing to wake.
        let mut addr = self.addr;
        if addr.ip().is_unspecified() {
            match addr {
                std::net::SocketAddr::V4(_) => {
                    addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
                }
                std::net::SocketAddr::V6(_) => {
                    addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
                }
            }
        }
        if let Ok(s) = TcpStream::connect_timeout(&addr, Duration::from_secs(1)) {
            drop(s);
        }
    }
}

impl Server {
    pub fn bind(addr: &str, handle: EngineHandle) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { listener, handle, stop: Arc::new(AtomicBool::new(false)), health: None })
    }

    /// Attach extra fields to the health verb (cluster role, replication lag).
    pub fn with_health(mut self, extra: HealthExtra) -> Server {
        self.health = Some(extra);
        self
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Handle that stops a running `serve` loop (flag + accept wake).
    pub fn shutdown_handle(&self) -> Result<Shutdown> {
        Ok(Shutdown { stop: Arc::clone(&self.stop), addr: self.listener.local_addr()? })
    }

    /// Serve until [`Shutdown::signal`]. Blocks the calling thread; every
    /// connect is accepted the moment it arrives (blocking accept — no
    /// poll-interval quantization on cold-connect latency).
    pub fn serve(&self) -> Result<()> {
        accept_loop(&self.listener, &self.stop, |stream| {
            let handle = self.handle.clone();
            let stop = Arc::clone(&self.stop);
            let health = self.health.clone();
            thread::spawn(move || {
                let _ = handle_connection(stream, handle, stop, health);
            });
        })
    }
}

/// Shared blocking accept loop (TCP line protocol + HTTP front end).
/// Checks the stop flag AFTER accept too: the shutdown wake arrives as a
/// connection; it (and any connect racing it) is dropped.
pub(crate) fn accept_loop(
    listener: &TcpListener,
    stop: &Arc<AtomicBool>,
    spawn: impl Fn(TcpStream),
) -> Result<()> {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                spawn(stream);
            }
            Err(e) => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                return Err(e.into());
            }
        }
    }
}

/// How often an idle connection wakes up to poll the stop flag.
pub(crate) const READ_POLL_INTERVAL: std::time::Duration =
    std::time::Duration::from_millis(100);

/// Hard cap on one request line. Anything larger gets a structured error
/// reply (and the connection closed) instead of growing the line buffer
/// without bound.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Bound on each reply write: a stalled client (full socket buffer, dead
/// peer) errors out of the connection thread instead of pinning it forever.
pub(crate) const WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

pub(crate) fn send_reply(writer: &mut TcpStream, reply: &Json) -> Result<()> {
    writer.write_all(reply.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

pub(crate) fn error_reply(msg: String) -> Json {
    Json::obj_from(vec![("error", Json::s(msg))])
}

fn handle_connection(
    stream: TcpStream,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
    health: Option<HealthExtra>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // A blocking `read_line` on an idle connection would never observe the
    // stop flag (the old shutdown hang): bound every read so the loop polls.
    stream.set_read_timeout(Some(READ_POLL_INTERVAL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // NB: on timeout, bytes already consumed stay appended to `line`;
        // the next read_line call continues the same partial line, so slow
        // writers lose nothing.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed
            Ok(_) => {
                if line.len() > MAX_LINE_BYTES {
                    send_reply(
                        &mut writer,
                        &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                    )?;
                    break;
                }
                if !line.trim().is_empty() {
                    let reply = process_line(&line, &handle, health.as_ref());
                    send_reply(&mut writer, &reply)?;
                }
                line.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Bound the buffer for a line still in flight too: a client
                // streaming an endless unterminated line gets refused here,
                // not an OOM.
                if line.len() > MAX_LINE_BYTES {
                    send_reply(
                        &mut writer,
                        &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                    )?;
                    break;
                }
                continue; // stop-flag poll point
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // read_line consumed through the newline before failing
                // UTF-8 validation, so the stream is still line-synced:
                // reply structurally and keep serving.
                send_reply(&mut writer, &error_reply("request is not valid UTF-8".into()))?;
                line.clear();
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Readiness view: engine breaker states + persistence generation, plus
/// whatever the attached [`HealthExtra`] reports (cluster role, replication
/// lag, shard-map epoch). Served on both fronts so drills can probe any
/// process the same way.
fn health_json(handle: &EngineHandle, extra: Option<&HealthExtra>) -> Json {
    let fields = match handle.stats() {
        Ok(s) => vec![
            ("ok", Json::Bool(true)),
            ("breaker_embed", Json::s(s.breaker_embed)),
            ("breaker_small", Json::s(s.breaker_small)),
            ("breaker_big", Json::s(s.breaker_big)),
            ("breaker_trips", Json::num(s.breaker_trips as f64)),
            ("persist_generation", Json::num(s.persist_generation as f64)),
            ("cache_size", Json::num(s.cache_size as f64)),
        ],
        Err(e) => vec![("ok", Json::Bool(false)), ("error", Json::s(format!("{e}")))],
    };
    let mut out = Json::obj_from(fields);
    if let Some(f) = extra {
        if let (Json::Obj(base), Json::Obj(add)) = (&mut out, f()) {
            base.extend(add);
        }
    }
    out
}

fn process_line(line: &str, handle: &EngineHandle, health: Option<&HealthExtra>) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return Json::obj_from(vec![("error", Json::s(format!("bad json: {e}")))])
        }
    };
    if req.opt("stats").is_some() {
        return match handle.stats() {
            Ok(s) => Json::obj_from(vec![
                ("requests", Json::num(s.requests as f64)),
                ("tweak_hits", Json::num(s.tweak_hits as f64)),
                ("exact_hits", Json::num(s.exact_hits as f64)),
                ("misses", Json::num(s.misses as f64)),
                ("cache_size", Json::num(s.cache_size as f64)),
                ("mean_batch_size", Json::num(s.mean_batch_size)),
                ("active_sessions", Json::num(s.active_sessions as f64)),
                ("waiting_sessions", Json::num(s.waiting_sessions as f64)),
                ("coalesced", Json::num(s.coalesced as f64)),
                ("batched_steps", Json::num(s.batched_steps as f64)),
                ("mean_active_slots", Json::num(s.mean_active_slots)),
                ("prefix_hits", Json::num(s.prefix_hits as f64)),
                ("prefix_misses", Json::num(s.prefix_misses as f64)),
                ("prefix_evictions", Json::num(s.prefix_evictions as f64)),
                (
                    "prefix_saved_tokens",
                    Json::num(s.prefix_saved_tokens as f64),
                ),
                ("cost_dollars", Json::num(s.cost_dollars)),
                ("baseline_dollars", Json::num(s.baseline_dollars)),
                ("latency_table", Json::s(s.latency_table)),
                ("persist_enabled", Json::Bool(s.persist_enabled)),
                ("persist_generation", Json::num(s.persist_generation as f64)),
                ("wal_bytes", Json::num(s.wal_bytes as f64)),
                ("wal_records", Json::num(s.wal_records as f64)),
                ("compactions", Json::num(s.compactions as f64)),
                (
                    "last_compaction_unix",
                    Json::num(s.last_compaction_unix as f64),
                ),
                ("recovered_entries", Json::num(s.recovered_entries as f64)),
                ("stages", stage_rows(&s.stage_latency)),
                ("traces_finished", Json::num(s.traces_finished as f64)),
                ("degraded_hits", Json::num(s.degraded_hits as f64)),
                ("shed", Json::num(s.shed as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("cancelled", Json::num(s.cancelled as f64)),
                ("embed_bypasses", Json::num(s.embed_bypasses as f64)),
                ("miss_retries", Json::num(s.miss_retries as f64)),
                ("breaker_trips", Json::num(s.breaker_trips as f64)),
                ("breaker_embed", Json::s(s.breaker_embed)),
                ("breaker_small", Json::s(s.breaker_small)),
                ("breaker_big", Json::s(s.breaker_big)),
            ]),
            Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
        };
    }
    if let Some(admin) = req.opt("admin") {
        return match admin.str() {
            Ok("snapshot") => match handle.snapshot() {
                Ok(r) => Json::obj_from(vec![
                    ("snapshot", Json::Bool(r.persist_enabled)),
                    ("generation", Json::num(r.generation as f64)),
                    ("entries", Json::num(r.entries as f64)),
                ]),
                Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
            },
            Ok("trace") => {
                let n = req.opt("n").and_then(|v| v.usize().ok()).unwrap_or(16);
                match handle.traces(n) {
                    Ok(r) => Json::obj_from(vec![
                        (
                            "traces",
                            Json::Arr(r.traces.iter().map(|t| t.to_json()).collect()),
                        ),
                        (
                            "slow",
                            Json::Arr(r.slow.iter().map(|t| t.to_json()).collect()),
                        ),
                        ("finished", Json::num(r.finished as f64)),
                        ("dropped", Json::num(r.dropped as f64)),
                    ]),
                    Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
                }
            }
            Ok("health") => health_json(handle, health),
            _ => Json::obj_from(vec![(
                "error",
                Json::s(
                    "unknown admin command (expected \"snapshot\", \"trace\", or \"health\")",
                ),
            )]),
        };
    }
    let query = match req.opt("query").and_then(|q| q.str().ok()) {
        Some(q) => q.to_string(),
        None => {
            return Json::obj_from(vec![(
                "error",
                Json::s("expected {\"query\": ...} or {\"stats\": true}"),
            )])
        }
    };
    // Read-mode override, used by the cluster router: "replica_read" serves
    // cache hits without mutating the cache (the entry space belongs to the
    // shard owner's WAL); "bypass" skips the cache entirely.
    let mode = match req.opt("mode").and_then(|m| m.str().ok()) {
        None => ReadMode::Default,
        Some("replica_read") => ReadMode::ReplicaRead,
        Some("bypass") => ReadMode::Bypass,
        Some(other) => {
            return error_reply(format!(
                "unknown mode {other:?} (expected \"replica_read\" or \"bypass\")"
            ))
        }
    };
    match handle.request_mode(&query, mode) {
        Ok(r) => Json::obj_from(vec![
            ("text", Json::s(r.text)),
            ("pathway", Json::s(pathway_str(r.pathway))),
            (
                "similarity",
                r.similarity.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
            ),
            ("latency_us", Json::num(r.total_micros as f64)),
        ]),
        Err(e) => Json::obj_from(vec![("error", Json::s(format!("{e}")))]),
    }
}

/// Per-stage × per-pathway quantile rows for the `stats` verb.
fn stage_rows(rows: &[StageSummary]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj_from(vec![
                    ("stage", Json::s(r.stage)),
                    ("pathway", Json::s(r.pathway)),
                    ("n", Json::num(r.n as f64)),
                    ("p50_us", Json::num(r.p50_us)),
                    ("p90_us", Json::num(r.p90_us)),
                    ("p99_us", Json::num(r.p99_us)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// OpenAI-compatible HTTP/SSE front end
// ---------------------------------------------------------------------------

/// Minimal HTTP/1.1 listener for `POST /v1/chat/completions`, one request
/// per connection (`Connection: close`). Non-streaming requests get a full
/// `chat.completion` JSON body; `"stream": true` gets SSE chunks. Runs
/// beside the TCP line-protocol [`Server`] on the same [`EngineHandle`].
pub struct HttpServer {
    listener: TcpListener,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
    health: Option<HealthExtra>,
}

impl HttpServer {
    pub fn bind(addr: &str, handle: EngineHandle) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http {addr}"))?;
        Ok(HttpServer {
            listener,
            handle,
            stop: Arc::new(AtomicBool::new(false)),
            health: None,
        })
    }

    /// Attach extra fields to `GET /healthz` (cluster role, replication lag).
    pub fn with_health(mut self, extra: HealthExtra) -> HttpServer {
        self.health = Some(extra);
        self
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Handle that stops a running `serve` loop (flag + accept wake).
    pub fn shutdown_handle(&self) -> Result<Shutdown> {
        Ok(Shutdown { stop: Arc::clone(&self.stop), addr: self.listener.local_addr()? })
    }

    /// Serve until [`Shutdown::signal`]. Blocks the calling thread.
    pub fn serve(&self) -> Result<()> {
        accept_loop(&self.listener, &self.stop, |stream| {
            let handle = self.handle.clone();
            let stop = Arc::clone(&self.stop);
            let health = self.health.clone();
            thread::spawn(move || {
                let _ = handle_http_connection(stream, handle, stop, health);
            });
        })
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one CRLF-terminated header line, polling the stop flag on read
/// timeouts. `None` means EOF (or shutdown) before a complete line.
fn read_http_line(
    reader: &mut BufReader<TcpStream>,
    stop: &AtomicBool,
) -> Result<Option<String>> {
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(line.trim_end_matches(['\r', '\n']).to_string())),
            Err(e) if would_block(&e) => {
                // Partial bytes stay in `line`; bound it like the TCP path.
                if line.len() > MAX_LINE_BYTES {
                    bail!("header line exceeds {MAX_LINE_BYTES} bytes");
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Read exactly `len` body bytes, polling the stop flag on read timeouts.
fn read_http_body(
    reader: &mut BufReader<TcpStream>,
    len: usize,
    stop: &AtomicBool,
) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        if stop.load(Ordering::Relaxed) {
            bail!("server shutting down");
        }
        match reader.read(&mut buf[filled..]) {
            Ok(0) => bail!("connection closed mid-body"),
            Ok(n) => filled += n,
            Err(e) if would_block(&e) => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(buf)
}

fn http_error(writer: &mut TcpStream, status: &str, msg: &str) -> Result<()> {
    let body = Json::obj_from(vec![(
        "error",
        Json::obj_from(vec![
            ("message", Json::s(msg)),
            ("type", Json::s("invalid_request_error")),
        ]),
    )])
    .to_string();
    write!(
        writer,
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    writer.flush()?;
    Ok(())
}

/// Content of the last `"role": "user"` message (the query the router sees).
fn last_user_message(req: &Json) -> Option<String> {
    let msgs = req.opt("messages")?.arr().ok()?;
    msgs.iter()
        .rev()
        .find(|m| m.opt("role").and_then(|r| r.str().ok()) == Some("user"))
        .and_then(|m| m.opt("content").and_then(|c| c.str().ok()))
        .map(str::to_string)
}

fn next_completion_id() -> String {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    format!("chatcmpl-{}", NEXT.fetch_add(1, Ordering::Relaxed))
}

fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

fn usage_json(r: &RoutedResponse) -> Json {
    Json::obj_from(vec![
        ("prompt_tokens", Json::num(r.usage.input_tokens as f64)),
        ("completion_tokens", Json::num(r.usage.output_tokens as f64)),
        (
            "total_tokens",
            Json::num((r.usage.input_tokens + r.usage.output_tokens) as f64),
        ),
    ])
}

/// The `"tweakllm"` extension object on final chunks / blocking replies:
/// which pathway served the request, the top-1 similarity, and the span
/// trace id to join against `{"admin": "trace"}`.
fn tweak_json(r: &RoutedResponse) -> Json {
    Json::obj_from(vec![
        ("pathway", Json::s(pathway_str(r.pathway))),
        (
            "similarity",
            r.similarity.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
        ),
        ("trace_id", Json::num(r.trace_id as f64)),
        ("latency_us", Json::num(r.total_micros as f64)),
    ])
}

/// One `chat.completion.chunk`. `role` only on the preamble chunk, `finish`
/// + `done` (usage & tweakllm extension) only on the final chunk.
fn chunk_json(
    id: &str,
    model: &str,
    created: u64,
    role: Option<&str>,
    content: &str,
    finish: Option<&str>,
    done: Option<&RoutedResponse>,
) -> Json {
    let mut delta = Vec::new();
    if let Some(role) = role {
        delta.push(("role", Json::s(role)));
    }
    if !content.is_empty() {
        delta.push(("content", Json::s(content)));
    }
    let choice = Json::obj_from(vec![
        ("index", Json::num(0.0)),
        ("delta", Json::obj_from(delta)),
        ("finish_reason", finish.map(Json::s).unwrap_or(Json::Null)),
    ]);
    let mut fields = vec![
        ("id", Json::s(id)),
        ("object", Json::s("chat.completion.chunk")),
        ("created", Json::num(created as f64)),
        ("model", Json::s(model)),
        ("choices", Json::Arr(vec![choice])),
    ];
    if let Some(r) = done {
        fields.push(("usage", usage_json(r)));
        fields.push(("tweakllm", tweak_json(r)));
    }
    Json::obj_from(fields)
}

fn send_sse(writer: &mut TcpStream, payload: &str) -> Result<()> {
    writer.write_all(b"data: ")?;
    writer.write_all(payload.as_bytes())?;
    writer.write_all(b"\n\n")?;
    writer.flush()?;
    Ok(())
}

fn handle_http_connection(
    stream: TcpStream,
    handle: EngineHandle,
    stop: Arc<AtomicBool>,
    health: Option<HealthExtra>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_POLL_INTERVAL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let request_line = match read_http_line(&mut reader, &stop)? {
        Some(l) if !l.is_empty() => l,
        _ => return Ok(()),
    };
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut content_length = 0usize;
    loop {
        let line = match read_http_line(&mut reader, &stop)? {
            Some(l) => l,
            None => return Ok(()),
        };
        if line.is_empty() {
            break; // blank line: headers done
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    if method == "GET" && path == "/healthz" {
        let body = health_json(&handle, health.as_ref()).to_string();
        write!(
            &mut writer,
            "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )?;
        writer.flush()?;
        return Ok(());
    }
    if path != "/v1/chat/completions" {
        let msg = "unknown path (expected POST /v1/chat/completions or GET /healthz)";
        return http_error(&mut writer, "404 Not Found", msg);
    }
    if method != "POST" {
        return http_error(&mut writer, "405 Method Not Allowed", "expected POST");
    }
    if content_length == 0 || content_length > MAX_LINE_BYTES {
        let msg = format!("request body must be 1..={MAX_LINE_BYTES} bytes");
        return http_error(&mut writer, "400 Bad Request", &msg);
    }
    let body = read_http_body(&mut reader, content_length, &stop)?;
    let req = match std::str::from_utf8(&body).ok().and_then(|s| Json::parse(s).ok()) {
        Some(j) => j,
        None => return http_error(&mut writer, "400 Bad Request", "body is not valid JSON"),
    };
    let query = match last_user_message(&req) {
        Some(q) => q,
        None => {
            let msg = "messages must contain a user message with string content";
            return http_error(&mut writer, "400 Bad Request", msg);
        }
    };
    let model =
        req.opt("model").and_then(|m| m.str().ok()).unwrap_or("tweakllm").to_string();
    let streaming = req.opt("stream").and_then(|s| s.bool().ok()).unwrap_or(false);
    let id = next_completion_id();
    let created = unix_now();
    if streaming {
        serve_sse(&mut writer, &handle, &query, &id, &model, created)
    } else {
        serve_completion(&mut writer, &handle, &query, &id, &model, created)
    }
}

fn serve_completion(
    writer: &mut TcpStream,
    handle: &EngineHandle,
    query: &str,
    id: &str,
    model: &str,
    created: u64,
) -> Result<()> {
    let r = match handle.request(query) {
        Ok(r) => r,
        Err(e) => {
            return http_error(writer, "500 Internal Server Error", &format!("{e:#}"))
        }
    };
    let message = Json::obj_from(vec![
        ("role", Json::s("assistant")),
        ("content", Json::s(r.text.clone())),
    ]);
    let choice = Json::obj_from(vec![
        ("index", Json::num(0.0)),
        ("message", message),
        ("finish_reason", Json::s("stop")),
    ]);
    let body = Json::obj_from(vec![
        ("id", Json::s(id)),
        ("object", Json::s("chat.completion")),
        ("created", Json::num(created as f64)),
        ("model", Json::s(model)),
        ("choices", Json::Arr(vec![choice])),
        ("usage", usage_json(&r)),
        ("tweakllm", tweak_json(&r)),
    ])
    .to_string();
    write!(
        writer,
        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    writer.flush()?;
    Ok(())
}

/// Pump one streamed completion out as SSE. A failed write (client gone,
/// stalled past [`WRITE_TIMEOUT`]) errors out of this function and drops
/// the receiver; the engine-side sink latches closed on its next send or
/// probe and the scheduler cancels the in-flight session.
fn serve_sse(
    writer: &mut TcpStream,
    handle: &EngineHandle,
    query: &str,
    id: &str,
    model: &str,
    created: u64,
) -> Result<()> {
    let rx = match handle.request_streaming(query) {
        Ok(rx) => rx,
        Err(e) => {
            return http_error(writer, "500 Internal Server Error", &format!("{e:#}"))
        }
    };
    write!(
        writer,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
         Cache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )?;
    // Role preamble chunk, per the OpenAI streaming shape.
    let preamble = chunk_json(id, model, created, Some("assistant"), "", None, None);
    send_sse(writer, &preamble.to_string())?;
    for ev in rx.iter() {
        match ev {
            StreamEvent::Delta(text) if text.is_empty() => {
                // Engine liveness probe → SSE comment: reaches the socket
                // (and fails if the client is gone) without touching the
                // payload any SSE client reassembles.
                writer.write_all(b":\n\n")?;
                writer.flush()?;
            }
            StreamEvent::Delta(text) => {
                let chunk = chunk_json(id, model, created, None, &text, None, None);
                send_sse(writer, &chunk.to_string())?;
            }
            StreamEvent::Done(resp) => {
                let fin = chunk_json(id, model, created, None, "", Some("stop"), Some(&resp));
                send_sse(writer, &fin.to_string())?;
                send_sse(writer, "[DONE]")?;
                break;
            }
            StreamEvent::Error(msg) => {
                let err = Json::obj_from(vec![(
                    "error",
                    Json::obj_from(vec![
                        ("message", Json::s(msg)),
                        ("type", Json::s("server_error")),
                    ]),
                )]);
                send_sse(writer, &err.to_string())?;
                send_sse(writer, "[DONE]")?;
                break;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client for the line protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn roundtrip(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
    }

    pub fn query(&mut self, text: &str) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("query", Json::s(text))]))
    }

    /// Query with a read-mode override (`"replica_read"` / `"bypass"`).
    pub fn query_mode(&mut self, text: &str, mode: &str) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![
            ("query", Json::s(text)),
            ("mode", Json::s(mode)),
        ]))
    }

    /// Readiness probe (`{"admin": "health"}`).
    pub fn health(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("admin", Json::s("health"))]))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("stats", Json::Bool(true))]))
    }

    /// Ask the server to snapshot its cache now (`{"admin": "snapshot"}`).
    pub fn snapshot(&mut self) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![("admin", Json::s("snapshot"))]))
    }

    /// Fetch the last `n` completed traces (`{"admin": "trace", "n": n}`).
    pub fn trace(&mut self, n: usize) -> Result<Json> {
        self.roundtrip(&Json::obj_from(vec![
            ("admin", Json::s("trace")),
            ("n", Json::num(n as f64)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pathway_strings() {
        assert_eq!(pathway_str(Pathway::Miss), "miss");
        assert_eq!(pathway_str(Pathway::TweakHit), "tweak_hit");
        assert_eq!(pathway_str(Pathway::ExactHit), "exact_hit");
        assert_eq!(pathway_str(Pathway::DegradedHit), "degraded_hit");
    }

    #[test]
    fn bad_json_reports_error() {
        // process_line must not panic on garbage — build a dummy handle by
        // checking only the parse branch (no engine call happens).
        let j = Json::parse("{\"x\": 1}").unwrap();
        assert!(j.opt("query").is_none());
    }

    #[test]
    fn last_user_message_picks_newest_user_turn() {
        let req = Json::parse(
            r#"{"messages": [
                {"role": "system", "content": "be terse"},
                {"role": "user", "content": "first"},
                {"role": "assistant", "content": "reply"},
                {"role": "user", "content": "second"}]}"#,
        )
        .unwrap();
        assert_eq!(last_user_message(&req).as_deref(), Some("second"));
        let none = Json::parse(r#"{"messages": [{"role": "system", "content": "s"}]}"#)
            .unwrap();
        assert!(last_user_message(&none).is_none());
        assert!(last_user_message(&Json::parse("{}").unwrap()).is_none());
    }

    #[test]
    fn chunk_json_openai_shapes() {
        let first = chunk_json("chatcmpl-1", "m", 7, Some("assistant"), "", None, None);
        assert_eq!(first.get("object").unwrap().str().unwrap(), "chat.completion.chunk");
        let delta = |j: &Json| j.get("choices").unwrap().arr().unwrap()[0].clone();
        assert_eq!(
            delta(&first).get("delta").unwrap().get("role").unwrap().str().unwrap(),
            "assistant"
        );
        assert_eq!(*delta(&first).get("finish_reason").unwrap(), Json::Null);

        let mid = chunk_json("chatcmpl-1", "m", 7, None, "tok", None, None);
        assert_eq!(
            delta(&mid).get("delta").unwrap().get("content").unwrap().str().unwrap(),
            "tok"
        );

        let resp = RoutedResponse {
            text: "full".into(),
            pathway: Pathway::TweakHit,
            similarity: Some(0.9),
            cached_query: None,
            cache_entry: None,
            usage: crate::cost::TokenUsage { input_tokens: 3, output_tokens: 5 },
            total_micros: 42,
            trace_id: 17,
        };
        let fin = chunk_json("chatcmpl-1", "m", 7, None, "", Some("stop"), Some(&resp));
        assert_eq!(delta(&fin).get("finish_reason").unwrap().str().unwrap(), "stop");
        assert_eq!(fin.get("usage").unwrap().get("total_tokens").unwrap().usize().unwrap(), 8);
        let ext = fin.get("tweakllm").unwrap();
        assert_eq!(ext.get("pathway").unwrap().str().unwrap(), "tweak_hit");
        assert_eq!(ext.get("trace_id").unwrap().usize().unwrap(), 17);
    }
}
