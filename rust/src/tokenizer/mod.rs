//! Deterministic hashed-word tokenizer.
//!
//! The substrate models carry random (untrained) weights, so the tokenizer's
//! only jobs are (a) determinism — the same text always maps to the same id
//! sequence, so identical/overlapping queries land close in embedding space —
//! and (b) a stable id range matching the compiled vocabulary. A hashed
//! word-level scheme does both without a learned vocab file: each normalized
//! word hashes into [FIRST_WORD_ID, vocab). Collisions are rare at our vocab
//! size and merely merge two words' embeddings — the same degradation a real
//! subword vocab has for rare words.
//!
//! Special ids mirror `python/compile/configs.py` and the artifact manifest.

use crate::util::rng::hash_bytes;

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;
pub const SEP_ID: i32 = 3;
pub const UNK_ID: i32 = 4;
pub const FIRST_WORD_ID: i32 = 5;

/// Function words whose encoder embedding rows are IDF-downweighted at AOT
/// time (mirror of `python/compile/configs.py::STOPWORDS`; the ids are
/// produced by this tokenizer's hash, mirrored in params.py). Kept here so
/// the native test embedder can reproduce the compiled encoder's behaviour.
pub const FUNCTION_WORDS: &str = "a an the is are was were be being been do \
does did done am can could should would will shall may might must i you he \
she we they it its my your me us them this that these those of for to in on \
at with about as by from into over under than then and or but not no nor so \
up down out off if else what which who whom whose how why when where come \
comes make makes made get gets got getting go going goes any some just \
really very please hey thanks thank appreciate question honest serious quick \
wondering curious tell know advance help i'm im ? ! . ,";

pub fn is_function_word(w: &str) -> bool {
    FUNCTION_WORDS.split(' ').any(|f| f == w)
}

#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab_size: i32,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size as i32 > FIRST_WORD_ID);
        Tokenizer { vocab_size: vocab_size as i32 }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size as usize
    }

    /// Lowercase, split on non-alphanumerics, keep sentence punctuation as
    /// its own token (punctuation carries intent: "?" vs "!").
    pub fn words(text: &str) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = String::new();
        for c in text.chars() {
            if c.is_alphanumeric() || c == '\'' {
                for lc in c.to_lowercase() {
                    cur.push(lc);
                }
            } else {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                if matches!(c, '?' | '!' | '.' | ',') {
                    out.push(c.to_string());
                }
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }

    /// Map one word to its id.
    pub fn word_id(&self, word: &str) -> i32 {
        if word.is_empty() {
            return UNK_ID;
        }
        let h = hash_bytes(word.as_bytes());
        FIRST_WORD_ID + (h % (self.vocab_size - FIRST_WORD_ID) as u64) as i32
    }

    /// Encode text to ids (no BOS/EOS framing).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        Self::words(text).iter().map(|w| self.word_id(w)).collect()
    }

    /// Encode, truncate to `max_len`, and right-pad with PAD_ID.
    /// Returns (ids, true_length_before_padding).
    pub fn encode_padded(&self, text: &str, max_len: usize) -> (Vec<i32>, usize) {
        let mut ids = self.encode(text);
        ids.truncate(max_len);
        let len = ids.len().max(1); // empty text still occupies one slot
        ids.resize(max_len, PAD_ID);
        if len == 1 && ids[0] == PAD_ID {
            ids[0] = UNK_ID;
        }
        (ids, len)
    }

    /// Encode a prompt for the decoder: BOS + ids (+ SEP joins segments),
    /// truncated to `max_len`. Returns (ids padded to max_len, length).
    pub fn encode_prompt(&self, segments: &[&str], max_len: usize) -> (Vec<i32>, usize) {
        let mut ids = vec![BOS_ID];
        for (i, seg) in segments.iter().enumerate() {
            if i > 0 {
                ids.push(SEP_ID);
            }
            ids.extend(self.encode(seg));
        }
        // Keep the head: for plain prompts the leading segment carries the
        // query, and truncation must never cut it in favour of the tail.
        // Tweak prompts (query last) go through `encode_prompt_suffixed`,
        // which reserves tail space instead.
        ids.truncate(max_len);
        let len = ids.len();
        ids.resize(max_len, PAD_ID);
        (ids, len)
    }

    /// Encode a prompt whose head must be bit-stable and whose tail must
    /// never be truncated away: BOS + `head_ids` + (SEP + segment) for each
    /// prefix segment, hard-truncated at `max_len - suffix_reserve`, then
    /// SEP + suffix, truncated to `max_len`. The truncation boundary for the
    /// prefix is FIXED (independent of the suffix length), so the prefix
    /// token ids are a pure function of `head_ids` + `prefix_segments` —
    /// the invariant the cross-request KV prefix cache keys on. Returns
    /// (ids padded to max_len, length).
    pub fn encode_prompt_suffixed(
        &self,
        head_ids: &[i32],
        prefix_segments: &[&str],
        suffix: &str,
        max_len: usize,
        suffix_reserve: usize,
    ) -> (Vec<i32>, usize) {
        assert!(suffix_reserve < max_len);
        let mut ids = vec![BOS_ID];
        ids.extend_from_slice(head_ids);
        for seg in prefix_segments {
            ids.push(SEP_ID);
            ids.extend(self.encode(seg));
        }
        ids.truncate(max_len - suffix_reserve);
        ids.push(SEP_ID);
        ids.extend(self.encode(suffix));
        ids.truncate(max_len);
        let len = ids.len();
        ids.resize(max_len, PAD_ID);
        (ids, len)
    }

    /// Render generated ids back to a pseudo-text. With a hashed vocab the
    /// mapping is not invertible; responses are rendered as stable word
    /// tokens (`w123`) — good enough for cache storage, dedup, and length
    /// accounting, which is all the serving pipeline needs.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS_ID || id == PAD_ID {
                break;
            }
            if id == BOS_ID || id == SEP_ID {
                continue;
            }
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(&format!("w{id}"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(8192)
    }

    #[test]
    fn deterministic() {
        let t = tok();
        assert_eq!(t.encode("Why is the sky blue?"), t.encode("Why is the sky blue?"));
    }

    #[test]
    fn case_insensitive() {
        let t = tok();
        assert_eq!(t.encode("Hello World"), t.encode("hello world"));
    }

    #[test]
    fn punctuation_is_tokenized() {
        let t = tok();
        let a = t.encode("why?");
        let b = t.encode("why");
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], b[0]);
    }

    #[test]
    fn ids_in_range() {
        let t = tok();
        for id in t.encode("the quick brown fox jumps over 42 lazy dogs!") {
            assert!((FIRST_WORD_ID..8192).contains(&id), "id={id}");
        }
    }

    #[test]
    fn shared_words_share_ids() {
        let t = tok();
        let a = t.encode("why is rust fast");
        let b = t.encode("why is python slow");
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        assert_ne!(a[2], b[2]);
    }

    #[test]
    fn padded_encode() {
        let t = tok();
        let (ids, len) = t.encode_padded("one two three", 8);
        assert_eq!(len, 3);
        assert_eq!(ids.len(), 8);
        assert!(ids[3..].iter().all(|&x| x == PAD_ID));
    }

    #[test]
    fn padded_truncates() {
        let t = tok();
        let long: String = (0..100).map(|i| format!("w{i} ")).collect();
        let (ids, len) = t.encode_padded(&long, 16);
        assert_eq!(len, 16);
        assert_eq!(ids.len(), 16);
    }

    #[test]
    fn empty_text_is_unk() {
        let t = tok();
        let (ids, len) = t.encode_padded("", 4);
        assert_eq!(len, 1);
        assert_eq!(ids[0], UNK_ID);
    }

    #[test]
    fn prompt_framing() {
        let t = tok();
        let (ids, len) = t.encode_prompt(&["query here", "cached stuff"], 32);
        assert_eq!(ids[0], BOS_ID);
        assert!(ids[..len].contains(&SEP_ID));
        assert!(len <= 32);
    }

    #[test]
    fn suffixed_prompt_prefix_is_stable_across_suffixes() {
        let t = tok();
        let head = t.encode("tailor the cached response");
        let long: String = (0..200).map(|i| format!("word{i} ")).collect();
        let segs: [&str; 2] = [&long, "cached reply"];
        let (a, _) = t.encode_prompt_suffixed(&head, &segs, "query one", 64, 16);
        let (b, _) = t.encode_prompt_suffixed(&head, &segs, "different two", 64, 16);
        // Prefix region identical regardless of suffix; SEP sits exactly at
        // the reserved boundary; suffix tokens differ after it.
        assert_eq!(a[..48], b[..48]);
        assert_eq!(a[48], SEP_ID);
        assert_eq!(b[48], SEP_ID);
        assert_ne!(a[49..], b[49..]);
        assert_eq!(a[0], BOS_ID);
    }

    #[test]
    fn suffixed_prompt_short_prefix_keeps_suffix_adjacent() {
        let t = tok();
        // Prefix shorter than the boundary: no forced gap, suffix follows
        // directly after its SEP and the rest is padding.
        let (ids, len) = t.encode_prompt_suffixed(&[], &["cq"], "new query", 32, 8);
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(ids[1], SEP_ID); // before "cq"
        assert_eq!(ids[3], SEP_ID); // before the suffix
        assert_eq!(len, 6);
        assert!(ids[len..].iter().all(|&x| x == PAD_ID));
    }

    #[test]
    fn hash_parity_with_python_mirror() {
        // Pinned against python/compile/params.py (hash_bytes / word_id):
        // any drift between the two hash implementations silently breaks
        // the encoder's stopword downweighting.
        assert_eq!(
            crate::util::rng::hash_bytes(b"coffee"),
            8988992976545371315u64
        );
        let t = tok();
        assert_eq!(t.word_id("coffee"), 2877);
        assert_eq!(t.word_id("the"), 2316);
        assert_eq!(t.word_id("?"), 8121);
    }

    #[test]
    fn function_words() {
        assert!(is_function_word("the"));
        assert!(is_function_word("?"));
        assert!(!is_function_word("coffee"));
        assert!(!is_function_word("good")); // polarity words are content
    }

    #[test]
    fn decode_skips_specials() {
        let t = tok();
        let s = t.decode(&[BOS_ID, 100, SEP_ID, 200, EOS_ID, 300]);
        assert_eq!(s, "w100 w200");
    }
}
