//! Deterministic hashed-word tokenizer.
//!
//! The substrate models carry random (untrained) weights, so the tokenizer's
//! only jobs are (a) determinism — the same text always maps to the same id
//! sequence, so identical/overlapping queries land close in embedding space —
//! and (b) a stable id range matching the compiled vocabulary. A hashed
//! word-level scheme does both without a learned vocab file: each normalized
//! word hashes into [FIRST_WORD_ID, vocab). Collisions are rare at our vocab
//! size and merely merge two words' embeddings — the same degradation a real
//! subword vocab has for rare words.
//!
//! Special ids mirror `python/compile/configs.py` and the artifact manifest.

use crate::util::rng::hash_bytes;

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;
pub const SEP_ID: i32 = 3;
pub const UNK_ID: i32 = 4;
pub const FIRST_WORD_ID: i32 = 5;

/// Function words whose encoder embedding rows are IDF-downweighted at AOT
/// time (mirror of `python/compile/configs.py::STOPWORDS`; the ids are
/// produced by this tokenizer's hash, mirrored in params.py). Kept here so
/// the native test embedder can reproduce the compiled encoder's behaviour.
pub const FUNCTION_WORDS: &str = "a an the is are was were be being been do \
does did done am can could should would will shall may might must i you he \
she we they it its my your me us them this that these those of for to in on \
at with about as by from into over under than then and or but not no nor so \
up down out off if else what which who whom whose how why when where come \
comes make makes made get gets got getting go going goes any some just \
really very please hey thanks thank appreciate question honest serious quick \
wondering curious tell know advance help i'm im ? ! . ,";

pub fn is_function_word(w: &str) -> bool {
    FUNCTION_WORDS.split(' ').any(|f| f == w)
}

#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab_size: i32,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size as i32 > FIRST_WORD_ID);
        Tokenizer { vocab_size: vocab_size as i32 }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size as usize
    }

    /// Lowercase, split on non-alphanumerics, keep sentence punctuation as
    /// its own token (punctuation carries intent: "?" vs "!").
    pub fn words(text: &str) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = String::new();
        for c in text.chars() {
            if c.is_alphanumeric() || c == '\'' {
                for lc in c.to_lowercase() {
                    cur.push(lc);
                }
            } else {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                if matches!(c, '?' | '!' | '.' | ',') {
                    out.push(c.to_string());
                }
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }

    /// Map one word to its id.
    pub fn word_id(&self, word: &str) -> i32 {
        if word.is_empty() {
            return UNK_ID;
        }
        let h = hash_bytes(word.as_bytes());
        FIRST_WORD_ID + (h % (self.vocab_size - FIRST_WORD_ID) as u64) as i32
    }

    /// Encode text to ids (no BOS/EOS framing).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        Self::words(text).iter().map(|w| self.word_id(w)).collect()
    }

    /// Encode, truncate to `max_len`, and right-pad with PAD_ID.
    /// Returns (ids, true_length_before_padding).
    pub fn encode_padded(&self, text: &str, max_len: usize) -> (Vec<i32>, usize) {
        let mut ids = self.encode(text);
        ids.truncate(max_len);
        let len = ids.len().max(1); // empty text still occupies one slot
        ids.resize(max_len, PAD_ID);
        if len == 1 && ids[0] == PAD_ID {
            ids[0] = UNK_ID;
        }
        (ids, len)
    }

    /// Encode a prompt for the decoder: BOS + ids (+ SEP joins segments),
    /// truncated to `max_len`. Returns (ids padded to max_len, length).
    pub fn encode_prompt(&self, segments: &[&str], max_len: usize) -> (Vec<i32>, usize) {
        let mut ids = vec![BOS_ID];
        for (i, seg) in segments.iter().enumerate() {
            if i > 0 {
                ids.push(SEP_ID);
            }
            ids.extend(self.encode(seg));
        }
        // Keep the head: for plain prompts the leading segment carries the
        // query, and truncation must never cut it in favour of the tail.
        // Tweak prompts (query last) go through `encode_prompt_suffixed`,
        // which reserves tail space instead.
        ids.truncate(max_len);
        let len = ids.len();
        ids.resize(max_len, PAD_ID);
        (ids, len)
    }

    /// Encode a prompt whose head must be bit-stable and whose tail must
    /// never be truncated away: BOS + `head_ids` + (SEP + segment) for each
    /// prefix segment, hard-truncated at `max_len - suffix_reserve`, then
    /// SEP + suffix, truncated to `max_len`. The truncation boundary for the
    /// prefix is FIXED (independent of the suffix length), so the prefix
    /// token ids are a pure function of `head_ids` + `prefix_segments` —
    /// the invariant the cross-request KV prefix cache keys on. Returns
    /// (ids padded to max_len, length).
    pub fn encode_prompt_suffixed(
        &self,
        head_ids: &[i32],
        prefix_segments: &[&str],
        suffix: &str,
        max_len: usize,
        suffix_reserve: usize,
    ) -> (Vec<i32>, usize) {
        assert!(suffix_reserve < max_len);
        let mut ids = vec![BOS_ID];
        ids.extend_from_slice(head_ids);
        for seg in prefix_segments {
            ids.push(SEP_ID);
            ids.extend(self.encode(seg));
        }
        ids.truncate(max_len - suffix_reserve);
        ids.push(SEP_ID);
        ids.extend(self.encode(suffix));
        ids.truncate(max_len);
        let len = ids.len();
        ids.resize(max_len, PAD_ID);
        (ids, len)
    }

    /// Render generated ids back to a pseudo-text. With a hashed vocab the
    /// mapping is not invertible; responses are rendered as stable word
    /// tokens (`w123`) — good enough for cache storage, dedup, and length
    /// accounting, which is all the serving pipeline needs.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS_ID || id == PAD_ID {
                break;
            }
            if id == BOS_ID || id == SEP_ID {
                continue;
            }
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(&format!("w{id}"));
        }
        out
    }

    /// Stateful incremental counterpart of [`Self::decode`] for streaming:
    /// feed ids as they are generated and get back exactly the text
    /// `decode` would have appended so far.
    pub fn stream_decoder(&self) -> StreamDecoder {
        StreamDecoder::new()
    }
}

/// Byte-level UTF-8 reassembly for streaming decoders: multi-byte characters
/// whose bytes arrive across separate pushes are held back until complete,
/// so a consumer never sees a replacement char for a merely *split* char.
/// Bytes that can never complete a character (genuinely invalid input) are
/// substituted with U+FFFD so a corrupt stream still terminates.
#[derive(Clone, Debug, Default)]
pub struct Utf8Guard {
    pending: Vec<u8>,
}

impl Utf8Guard {
    pub fn new() -> Self {
        Utf8Guard { pending: Vec::new() }
    }

    /// Feed raw bytes; returns every character that is now complete.
    /// An incomplete trailing sequence is held back for the next push.
    pub fn push(&mut self, bytes: &[u8]) -> String {
        self.pending.extend_from_slice(bytes);
        let buf = std::mem::take(&mut self.pending);
        let mut out = String::new();
        let mut rest = &buf[..];
        loop {
            match std::str::from_utf8(rest) {
                Ok(s) => {
                    out.push_str(s);
                    rest = &[];
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(std::str::from_utf8(&rest[..valid]).expect("valid prefix"));
                    match e.error_len() {
                        // Incomplete trailing sequence: more bytes may still
                        // complete it — hold it back instead of emitting a
                        // replacement char mid-stream.
                        None => {
                            rest = &rest[valid..];
                            break;
                        }
                        // Invalid bytes can never complete: substitute and
                        // keep scanning the remainder.
                        Some(n) => {
                            out.push('\u{FFFD}');
                            rest = &rest[valid + n..];
                        }
                    }
                }
            }
        }
        self.pending = rest.to_vec();
        out
    }

    /// End of stream: a held-back tail can no longer complete, so it renders
    /// as replacement chars (lossy) rather than being dropped silently.
    pub fn flush(&mut self) -> String {
        let buf = std::mem::take(&mut self.pending);
        String::from_utf8_lossy(&buf).into_owned()
    }

    pub fn has_pending(&self) -> bool {
        !self.pending.is_empty()
    }
}

/// Incremental [`Tokenizer::decode`]: push newly generated ids as they land
/// and receive the exact text `decode` would have appended, UTF-8-safe at
/// every step. Invariant (unit-tested): the concatenation of every
/// `push_ids` return value plus `finish()`, over ANY split of an id stream,
/// equals one-shot `decode` of the whole stream.
#[derive(Clone, Debug, Default)]
pub struct StreamDecoder {
    guard: Utf8Guard,
    emitted_any: bool,
    done: bool,
}

impl StreamDecoder {
    pub fn new() -> Self {
        StreamDecoder::default()
    }

    /// Feed the next span of generated ids; returns the text to append.
    /// EOS/PAD latch the stream done (ids after them are ignored), BOS/SEP
    /// are skipped, and words are space-joined exactly like `decode`.
    pub fn push_ids(&mut self, ids: &[i32]) -> String {
        if self.done {
            return String::new();
        }
        let mut bytes = Vec::new();
        for &id in ids {
            if id == EOS_ID || id == PAD_ID {
                self.done = true;
                break;
            }
            if id == BOS_ID || id == SEP_ID {
                continue;
            }
            if self.emitted_any {
                bytes.push(b' ');
            }
            bytes.extend_from_slice(format!("w{id}").as_bytes());
            self.emitted_any = true;
        }
        self.guard.push(&bytes)
    }

    /// End of stream: release any held-back bytes.
    pub fn finish(&mut self) -> String {
        self.done = true;
        self.guard.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(8192)
    }

    #[test]
    fn deterministic() {
        let t = tok();
        assert_eq!(t.encode("Why is the sky blue?"), t.encode("Why is the sky blue?"));
    }

    #[test]
    fn case_insensitive() {
        let t = tok();
        assert_eq!(t.encode("Hello World"), t.encode("hello world"));
    }

    #[test]
    fn punctuation_is_tokenized() {
        let t = tok();
        let a = t.encode("why?");
        let b = t.encode("why");
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], b[0]);
    }

    #[test]
    fn ids_in_range() {
        let t = tok();
        for id in t.encode("the quick brown fox jumps over 42 lazy dogs!") {
            assert!((FIRST_WORD_ID..8192).contains(&id), "id={id}");
        }
    }

    #[test]
    fn shared_words_share_ids() {
        let t = tok();
        let a = t.encode("why is rust fast");
        let b = t.encode("why is python slow");
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        assert_ne!(a[2], b[2]);
    }

    #[test]
    fn padded_encode() {
        let t = tok();
        let (ids, len) = t.encode_padded("one two three", 8);
        assert_eq!(len, 3);
        assert_eq!(ids.len(), 8);
        assert!(ids[3..].iter().all(|&x| x == PAD_ID));
    }

    #[test]
    fn padded_truncates() {
        let t = tok();
        let long: String = (0..100).map(|i| format!("w{i} ")).collect();
        let (ids, len) = t.encode_padded(&long, 16);
        assert_eq!(len, 16);
        assert_eq!(ids.len(), 16);
    }

    #[test]
    fn empty_text_is_unk() {
        let t = tok();
        let (ids, len) = t.encode_padded("", 4);
        assert_eq!(len, 1);
        assert_eq!(ids[0], UNK_ID);
    }

    #[test]
    fn prompt_framing() {
        let t = tok();
        let (ids, len) = t.encode_prompt(&["query here", "cached stuff"], 32);
        assert_eq!(ids[0], BOS_ID);
        assert!(ids[..len].contains(&SEP_ID));
        assert!(len <= 32);
    }

    #[test]
    fn suffixed_prompt_prefix_is_stable_across_suffixes() {
        let t = tok();
        let head = t.encode("tailor the cached response");
        let long: String = (0..200).map(|i| format!("word{i} ")).collect();
        let segs: [&str; 2] = [&long, "cached reply"];
        let (a, _) = t.encode_prompt_suffixed(&head, &segs, "query one", 64, 16);
        let (b, _) = t.encode_prompt_suffixed(&head, &segs, "different two", 64, 16);
        // Prefix region identical regardless of suffix; SEP sits exactly at
        // the reserved boundary; suffix tokens differ after it.
        assert_eq!(a[..48], b[..48]);
        assert_eq!(a[48], SEP_ID);
        assert_eq!(b[48], SEP_ID);
        assert_ne!(a[49..], b[49..]);
        assert_eq!(a[0], BOS_ID);
    }

    #[test]
    fn suffixed_prompt_short_prefix_keeps_suffix_adjacent() {
        let t = tok();
        // Prefix shorter than the boundary: no forced gap, suffix follows
        // directly after its SEP and the rest is padding.
        let (ids, len) = t.encode_prompt_suffixed(&[], &["cq"], "new query", 32, 8);
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(ids[1], SEP_ID); // before "cq"
        assert_eq!(ids[3], SEP_ID); // before the suffix
        assert_eq!(len, 6);
        assert!(ids[len..].iter().all(|&x| x == PAD_ID));
    }

    #[test]
    fn hash_parity_with_python_mirror() {
        // Pinned against python/compile/params.py (hash_bytes / word_id):
        // any drift between the two hash implementations silently breaks
        // the encoder's stopword downweighting.
        assert_eq!(
            crate::util::rng::hash_bytes(b"coffee"),
            8988992976545371315u64
        );
        let t = tok();
        assert_eq!(t.word_id("coffee"), 2877);
        assert_eq!(t.word_id("the"), 2316);
        assert_eq!(t.word_id("?"), 8121);
    }

    #[test]
    fn function_words() {
        assert!(is_function_word("the"));
        assert!(is_function_word("?"));
        assert!(!is_function_word("coffee"));
        assert!(!is_function_word("good")); // polarity words are content
    }

    #[test]
    fn decode_skips_specials() {
        let t = tok();
        let s = t.decode(&[BOS_ID, 100, SEP_ID, 200, EOS_ID, 300]);
        assert_eq!(s, "w100 w200");
    }

    #[test]
    fn utf8_guard_never_splits_multibyte_chars() {
        // 2-, 3-, and 4-byte sequences split at every byte boundary: the
        // split must never surface a replacement char mid-stream, and the
        // concatenation must reproduce the original text exactly.
        let text = "aé€🦀b";
        let bytes = text.as_bytes();
        for split in 0..=bytes.len() {
            let mut g = Utf8Guard::new();
            let mut out = g.push(&bytes[..split]);
            out.push_str(&g.push(&bytes[split..]));
            out.push_str(&g.flush());
            assert!(!out.contains('\u{FFFD}'), "split at {split}: {out:?}");
            assert_eq!(out, text, "split at {split}");
        }
        // byte-at-a-time delivery
        let mut g = Utf8Guard::new();
        let mut out = String::new();
        for &b in bytes {
            out.push_str(&g.push(&[b]));
        }
        out.push_str(&g.flush());
        assert_eq!(out, text);
    }

    #[test]
    fn utf8_guard_substitutes_invalid_bytes() {
        let mut g = Utf8Guard::new();
        assert_eq!(g.push(&[0xFF, b'a']), "\u{FFFD}a");
        assert_eq!(g.push(&[0x80]), "\u{FFFD}"); // lone continuation byte
        assert!(!g.has_pending());
        assert!(g.flush().is_empty());
    }

    #[test]
    fn utf8_guard_flush_renders_incomplete_tail() {
        let mut g = Utf8Guard::new();
        // First two bytes of € (E2 82 AC): held back while the stream is
        // live, substituted at end-of-stream when they can never complete.
        assert_eq!(g.push(&[0xE2, 0x82]), "");
        assert!(g.has_pending());
        assert_eq!(g.flush(), "\u{FFFD}");
    }

    #[test]
    fn stream_decoder_concat_equals_one_shot_decode() {
        let t = tok();
        let ids = [BOS_ID, 100, SEP_ID, 200, 300, EOS_ID, 400];
        for split in 0..=ids.len() {
            let mut d = t.stream_decoder();
            let mut out = d.push_ids(&ids[..split]);
            out.push_str(&d.push_ids(&ids[split..]));
            out.push_str(&d.finish());
            assert_eq!(out, t.decode(&ids), "split at {split}");
        }
        // one id at a time
        let mut d = t.stream_decoder();
        let mut out = String::new();
        for id in ids {
            out.push_str(&d.push_ids(&[id]));
        }
        out.push_str(&d.finish());
        assert_eq!(out, t.decode(&ids));
    }

    #[test]
    fn stream_decoder_latches_on_eos() {
        let t = tok();
        let mut d = t.stream_decoder();
        assert_eq!(d.push_ids(&[100, EOS_ID]), "w100");
        assert_eq!(d.push_ids(&[200]), "");
        assert_eq!(d.finish(), "");
    }
}
