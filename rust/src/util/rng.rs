//! Deterministic PRNG substrate.
//!
//! No external RNG crates are available offline, and reproducibility of every
//! experiment (dataset generation, survey simulation, debate noise, sampling)
//! is a hard requirement, so we carry our own: SplitMix64 for seeding /
//! hashing and a PCG32-style generator for streams. Every consumer takes an
//! explicit seed; nothing reads OS entropy.

/// SplitMix64 step — also used as a general 64-bit mixer/hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash arbitrary bytes to a u64 (FNV-1a folded through splitmix).
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    let mut s = h;
    splitmix64(&mut s)
}

/// Hash a string plus a stream tag — used to derive independent substreams.
pub fn hash_tagged(seed: u64, tag: &str) -> u64 {
    let mut s = seed ^ hash_bytes(tag.as_bytes());
    splitmix64(&mut s)
}

/// Small, fast, deterministic PRNG (xoshiro256++).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent substream derived from this seed and a tag.
    pub fn substream(seed: u64, tag: &str) -> Self {
        Rng::new(hash_tagged(seed, tag))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free mapping is fine for our (non-crypto) use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize(hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller; one value per call, simple over fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (via inverse-CDF on
    /// precomputed weights it's O(n); callers cache a `ZipfSampler` instead
    /// for hot loops).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Precomputed Zipf sampler (popularity-skewed topic selection in the
/// synthetic chat traces).
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, exponent: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn substreams_differ() {
        let mut a = Rng::substream(7, "alpha");
        let mut b = Rng::substream(7, "beta");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(5, 10);
            assert!((5..10).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let z = ZipfSampler::new(1000, 1.1);
        let mut r = Rng::new(5);
        let mut head = 0usize;
        for _ in 0..10_000 {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-10 of 1000 topics should absorb a large share under zipf(1.1)
        assert!(head > 2_000, "head={head}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let mut c = [0usize; 3];
        for _ in 0..6000 {
            c[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(c[2] > c[0] + c[1]);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(1);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }
}
