//! Minimal JSON substrate (parser + writer).
//!
//! serde is not available offline, and the runtime only needs JSON for two
//! things: reading `artifacts/manifest.json` and exchanging requests/results
//! over the line-delimited server protocol + bench reports. This is a small
//! recursive-descent parser over the full JSON grammar (numbers as f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors (ergonomic manifest reading) ----

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.f64()? as usize)
    }

    pub fn i64(&self) -> Result<i64> {
        Ok(self.f64()? as i64)
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- construction helpers ----

    pub fn obj_from(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn s(x: impl Into<String>) -> Json {
        Json::Str(x.into())
    }

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            self.pos += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // Surrogate pairs: only BMP needed for our files.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Re-decode multibyte UTF-8 sequence.
                    self.pos -= 1;
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| anyhow!("invalid utf8 in string"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected , or ] at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected , or }} at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","shape":[4,384],"ok":true,"f":0.25}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t".into());
        let enc = j.to_string();
        assert_eq!(Json::parse(&enc).unwrap(), j);
    }

    #[test]
    fn unicode() {
        let j = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j.str().unwrap(), "café é");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }
}
