//! Small statistics substrate: summaries, percentiles, histograms, and a
//! Welford online accumulator. Used by the metrics layer, the bench harness,
//! and every eval figure.

/// Percentile of a *sorted* slice (nearest-rank with linear interpolation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Full five-number-ish summary of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary::default();
        }
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(1) as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[n - 1],
        }
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Combine two accumulators (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets. Used for similarity-band and latency distributions.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(buckets > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; buckets], total: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64)
            .floor();
        let idx = (b.max(0.0) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn bucket_bounds(&self, i: usize) -> (f64, f64) {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (self.lo + i as f64 * w, self.lo + (i + 1) as f64 * w)
    }

    /// Fraction of samples at or above `x`.
    pub fn frac_at_least(&self, x: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let (blo, _) = self.bucket_bounds(i);
            if blo + w * 0.5 >= x {
                acc += c;
            }
        }
        acc as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 100.0);
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn online_merge_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0];
        let ys = [9.0, 2.0, 6.0];
        let mut a = Online::new();
        for &x in &xs {
            a.push(x);
        }
        let mut b = Online::new();
        for &y in &ys {
            b.push(y);
        }
        a.merge(&b);
        let all: Vec<f64> = xs.iter().chain(&ys).copied().collect();
        let s = Summary::of(&all);
        assert_eq!(a.count(), 8);
        assert!((a.mean() - s.mean).abs() < 1e-12);
        assert!((a.std() - s.std).abs() < 1e-9);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 9.0);

        let mut empty = Online::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 8);
        assert!((empty.mean() - s.mean).abs() < 1e-12);
    }

    #[test]
    fn histogram_cdf() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 100.0);
        }
        let f = h.frac_at_least(0.8);
        assert!((f - 0.2).abs() < 0.05, "f={f}");
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(-5.0);
        h.push(7.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }
}
