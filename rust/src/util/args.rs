//! Tiny CLI argument substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow!("--{key} expects a boolean, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("serve --port 9000 trace.jsonl --verbose");
        assert_eq!(a.positional, vec!["serve", "trace.jsonl"]);
        assert_eq!(a.str("port", ""), "9000");
        assert!(a.bool("verbose", false).unwrap());
    }

    #[test]
    fn eq_form() {
        let a = parse("--threshold=0.7 --n=100");
        assert_eq!(a.f64("threshold", 0.0).unwrap(), 0.7);
        assert_eq!(a.usize("n", 0).unwrap(), 100);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("missing", 42).unwrap(), 42);
        assert!(!a.bool("missing", false).unwrap());
    }

    #[test]
    fn bad_types_error() {
        let a = parse("--n notanumber");
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn flag_before_positional() {
        // a value-less flag followed by a positional: last flag grabs it,
        // so flags must come after positionals or use `=`. Document via test.
        let a = parse("--fast run");
        assert_eq!(a.str("fast", ""), "run");
    }
}
