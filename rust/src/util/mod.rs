//! Shared substrates: PRNG, statistics, JSON, argument parsing, threadpool.
//!
//! Everything here exists because the offline vendor set contains only the
//! `xla` crate closure — serde/clap/rand/tokio/criterion are reimplemented
//! minimally (and tested) rather than stubbed.

pub mod args;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use args::Args;
pub use json::Json;
pub use rng::{Rng, ZipfSampler};
pub use stats::{Histogram, Online, Summary};
pub use threadpool::ThreadPool;

/// Monotonic wall-clock helper for latency measurement.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Dot product of two equal-length f32 slices (the vector-search hot loop;
/// see `cache::flat` for the blocked/unrolled variant used in the scan).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// L2-normalize a vector in place; returns the original norm.
pub fn normalize(v: &mut [f32]) -> f32 {
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_normalize() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((dot(&v, &v) - 1.0).abs() < 1e-6);
    }
}
