//! Fixed-size threadpool substrate (tokio is unavailable offline).
//!
//! The coordinator's event loop is channel-based: the server front-end, the
//! bench harnesses, and the sharded vector scan (`cache::segment`) submit
//! closures; worker threads execute them. Model compute stays serialized on
//! the PJRT CPU client; the pool's job is data-parallel scan fan-out plus
//! overlapping tokenization/search/bookkeeping with generation.
//!
//! The submit side is a `Mutex<Sender>` so the pool is `Sync`: the vector
//! index holds it behind an `Arc` and must stay `Send` (`VectorIndex: Send`),
//! which a bare `mpsc::Sender` field would break.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            workers.push(
                thread::Builder::new()
                    .name(format!("tweakllm-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(Mutex::new(tx)), workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .lock()
            .expect("pool submit lock poisoned")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run a batch of jobs and wait for all of them.
    pub fn scoped_batch<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'static,
    {
        let (done_tx, done_rx) = mpsc::channel();
        let n = jobs.len();
        for job in jobs {
            let done = done_tx.clone();
            self.execute(move || {
                job();
                let _ = done.send(());
            });
        }
        for _ in 0..n {
            done_rx.recv().expect("job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.scoped_batch(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must join, so all 10 ran
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
