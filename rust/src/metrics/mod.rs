//! Serving metrics: latency recorders, counters, and the per-pathway
//! breakdown the e2e driver reports.

use std::collections::BTreeMap;

use crate::util::{Summary};

/// Latency samples per named stage (embed, search, prefill, decode, ...).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples: BTreeMap<String, Vec<f64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, stage: &str, micros: f64) {
        self.samples.entry(stage.to_string()).or_default().push(micros);
    }

    pub fn record_duration(&mut self, stage: &str, d: std::time::Duration) {
        self.record(stage, d.as_micros() as f64);
    }

    pub fn summary(&self, stage: &str) -> Option<Summary> {
        self.samples.get(stage).map(|s| Summary::of(s))
    }

    pub fn stages(&self) -> impl Iterator<Item = (&String, Summary)> {
        self.samples.iter().map(|(k, v)| (k, Summary::of(v)))
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        for (k, v) in &other.samples {
            self.samples.entry(k.clone()).or_default().extend(v);
        }
    }

    /// Formatted table (micros) for reports.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<18} {:>8} {:>10} {:>10} {:>10} {:>10}\n",
            "stage", "n", "mean_us", "p50_us", "p90_us", "p99_us"
        ));
        for (stage, s) in self.stages() {
            out.push_str(&format!(
                "{:<18} {:>8} {:>10.0} {:>10.0} {:>10.0} {:>10.0}\n",
                stage, s.n, s.mean, s.p50, s.p90, s.p99
            ));
        }
        out
    }
}

/// Monotonic counters.
#[derive(Debug, Default, Clone)]
pub struct Counters {
    inner: BTreeMap<String, u64>,
}

impl Counters {
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, by: u64) {
        *self.inner.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &u64)> {
        self.inner.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record("embed", i as f64);
        }
        let s = r.summary("embed").unwrap();
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(r.summary("missing").is_none());
    }

    #[test]
    fn merge_recorders() {
        let mut a = LatencyRecorder::new();
        a.record("x", 1.0);
        let mut b = LatencyRecorder::new();
        b.record("x", 3.0);
        b.record("y", 5.0);
        a.merge(&b);
        assert_eq!(a.summary("x").unwrap().n, 2);
        assert_eq!(a.summary("y").unwrap().n, 1);
    }

    #[test]
    fn counters() {
        let mut c = Counters::default();
        c.inc("hits");
        c.add("hits", 4);
        assert_eq!(c.get("hits"), 5);
        assert_eq!(c.get("misses"), 0);
    }

    #[test]
    fn table_renders() {
        let mut r = LatencyRecorder::new();
        r.record("decode", 1234.0);
        let t = r.table();
        assert!(t.contains("decode"));
        assert!(t.contains("p99_us"));
    }
}
