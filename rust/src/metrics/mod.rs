//! Serving metrics: latency recorders, counters, and the per-pathway
//! breakdown the e2e driver reports.
//!
//! `LatencyRecorder` is bounded-memory: each stage keeps a Welford
//! accumulator, a log-bucketed histogram, and a small exact-sample prefix.
//! Percentiles are exact while a stage has at most [`EXACT_SAMPLE_CAP`]
//! samples and histogram-approximated (<= 1/[`LOG_HIST_SUB`] relative error)
//! beyond that, so a long-running server never grows per-request state.

use std::collections::BTreeMap;

use crate::util::{Online, Summary};

/// Exact samples retained per stage before falling back to the histogram.
pub const EXACT_SAMPLE_CAP: usize = 4096;

/// Linear sub-buckets per power-of-two octave in [`LogHistogram`].
pub const LOG_HIST_SUB: usize = 8;

/// Octaves covered by [`LogHistogram`]: values in `[1, 2^40)` microseconds
/// (~12.7 days) resolve to a bucket; everything below clamps to bucket 0.
const LOG_HIST_OCTAVES: usize = 40;

/// HDR-style log-bucketed histogram over non-negative values (micros).
///
/// Buckets are `LOG_HIST_SUB` linear subdivisions of each power-of-two
/// octave, so the worst-case relative quantile error is `1 / LOG_HIST_SUB`
/// (12.5%) at constant memory (`40 * 8` u64 counts).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram { counts: vec![0; LOG_HIST_OCTAVES * LOG_HIST_SUB], total: 0 }
    }

    fn bucket(x: f64) -> usize {
        if !x.is_finite() || x < 1.0 {
            return 0;
        }
        let octave = (x.log2().floor() as usize).min(LOG_HIST_OCTAVES - 1);
        let base = (octave as f64).exp2();
        let sub = (((x / base) - 1.0) * LOG_HIST_SUB as f64).floor();
        let sub = (sub.max(0.0) as usize).min(LOG_HIST_SUB - 1);
        octave * LOG_HIST_SUB + sub
    }

    /// Midpoint of bucket `i` (the value reported for quantiles landing
    /// in it). Bucket width is `2^octave / LOG_HIST_SUB`.
    fn bucket_mid(i: usize) -> f64 {
        let octave = i / LOG_HIST_SUB;
        let sub = i % LOG_HIST_SUB;
        let base = (octave as f64).exp2();
        base * (1.0 + sub as f64 / LOG_HIST_SUB as f64) + base / (2 * LOG_HIST_SUB) as f64
    }

    pub fn record(&mut self, x: f64) {
        self.counts[Self::bucket(x)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Quantile `q` in [0, 1] via cumulative walk; 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(self.counts.len() - 1)
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Per-stage accumulator: exact prefix + running moments + histogram.
#[derive(Debug)]
struct StageAcc {
    online: Online,
    exact: Vec<f64>,
    hist: LogHistogram,
}

impl Default for StageAcc {
    fn default() -> Self {
        StageAcc { online: Online::new(), exact: Vec::new(), hist: LogHistogram::new() }
    }
}

impl StageAcc {
    fn push(&mut self, x: f64) {
        self.online.push(x);
        self.hist.record(x);
        if self.exact.len() < EXACT_SAMPLE_CAP {
            self.exact.push(x);
        }
    }

    fn summary(&self) -> Summary {
        let n = self.online.count() as usize;
        if n == self.exact.len() {
            return Summary::of(&self.exact);
        }
        Summary {
            n,
            mean: self.online.mean(),
            std: self.online.std(),
            min: self.online.min(),
            p50: self.hist.quantile(0.50),
            p90: self.hist.quantile(0.90),
            p99: self.hist.quantile(0.99),
            max: self.online.max(),
        }
    }

    fn merge(&mut self, other: &StageAcc) {
        self.online.merge(&other.online);
        self.hist.merge(&other.hist);
        for &x in &other.exact {
            if self.exact.len() == EXACT_SAMPLE_CAP {
                break;
            }
            self.exact.push(x);
        }
    }
}

/// Latency samples per named stage (embed, search, prefill, decode, ...).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples: BTreeMap<String, StageAcc>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, stage: &str, micros: f64) {
        self.samples.entry(stage.to_string()).or_default().push(micros);
    }

    pub fn record_duration(&mut self, stage: &str, d: std::time::Duration) {
        self.record(stage, d.as_micros() as f64);
    }

    pub fn summary(&self, stage: &str) -> Option<Summary> {
        self.samples.get(stage).map(|s| s.summary())
    }

    pub fn stages(&self) -> impl Iterator<Item = (&String, Summary)> {
        self.samples.iter().map(|(k, v)| (k, v.summary()))
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        for (k, v) in &other.samples {
            self.samples.entry(k.clone()).or_default().merge(v);
        }
    }

    /// Formatted table (micros) for reports.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<18} {:>8} {:>10} {:>10} {:>10} {:>10}\n",
            "stage", "n", "mean_us", "p50_us", "p90_us", "p99_us"
        ));
        for (stage, s) in self.stages() {
            out.push_str(&format!(
                "{stage:<18} {:>8} {:>10.0} {:>10.0} {:>10.0} {:>10.0}\n",
                s.n, s.mean, s.p50, s.p90, s.p99
            ));
        }
        out
    }
}

/// Monotonic counters.
#[derive(Debug, Default, Clone)]
pub struct Counters {
    inner: BTreeMap<String, u64>,
}

impl Counters {
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, by: u64) {
        *self.inner.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &u64)> {
        self.inner.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record("embed", i as f64);
        }
        let s = r.summary("embed").unwrap();
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(r.summary("missing").is_none());
    }

    #[test]
    fn merge_recorders() {
        let mut a = LatencyRecorder::new();
        a.record("x", 1.0);
        let mut b = LatencyRecorder::new();
        b.record("x", 3.0);
        b.record("y", 5.0);
        a.merge(&b);
        assert_eq!(a.summary("x").unwrap().n, 2);
        assert_eq!(a.summary("y").unwrap().n, 1);
    }

    #[test]
    fn counters() {
        let mut c = Counters::default();
        c.inc("hits");
        c.add("hits", 4);
        assert_eq!(c.get("hits"), 5);
        assert_eq!(c.get("misses"), 0);
    }

    #[test]
    fn table_renders() {
        let mut r = LatencyRecorder::new();
        r.record("decode", 1234.0);
        let t = r.table();
        assert!(t.contains("decode"));
        assert!(t.contains("p99_us"));
    }

    #[test]
    fn log_histogram_quantiles_bounded_error() {
        let mut h = LogHistogram::new();
        for i in 1..=10_000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 10_000);
        for (q, expect) in [(0.5, 5_000.0), (0.9, 9_000.0), (0.99, 9_900.0)] {
            let got = h.quantile(q);
            let rel = (got - expect).abs() / expect;
            assert!(rel <= 0.13, "q={q} got={got} expect={expect} rel={rel}");
        }
    }

    #[test]
    fn log_histogram_edge_values() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(0.2);
        h.record(f64::NAN);
        h.record(1e30); // clamps to the top octave without panicking
        assert_eq!(h.count(), 4);
        assert!(h.quantile(0.0) >= 1.0);
    }

    #[test]
    fn recorder_memory_is_bounded_past_cap() {
        let mut r = LatencyRecorder::new();
        let n = EXACT_SAMPLE_CAP + 6_000;
        for i in 1..=n {
            r.record("total", i as f64);
        }
        let stage = r.samples.get("total").unwrap();
        assert_eq!(stage.exact.len(), EXACT_SAMPLE_CAP);
        let s = r.summary("total").unwrap();
        assert_eq!(s.n, n);
        // mean/min/max stay exact via the online accumulator
        assert!((s.mean - (n as f64 + 1.0) / 2.0).abs() < 1e-6 * n as f64);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, n as f64);
        // percentiles come from the histogram: bounded relative error
        let expect = n as f64 / 2.0;
        assert!((s.p50 - expect).abs() / expect <= 0.13, "p50={}", s.p50);
    }

    #[test]
    fn summaries_exact_below_cap() {
        let mut r = LatencyRecorder::new();
        for i in 1..=101 {
            r.record("x", i as f64);
        }
        let s = r.summary("x").unwrap();
        assert_eq!(s.p50, 51.0);
        assert_eq!(s.max, 101.0);
    }

    #[test]
    fn merged_recorders_past_cap_stay_bounded() {
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        for i in 0..EXACT_SAMPLE_CAP {
            a.record("x", i as f64);
            b.record("x", i as f64);
        }
        a.merge(&b);
        let s = a.summary("x").unwrap();
        assert_eq!(s.n, 2 * EXACT_SAMPLE_CAP);
        let stage = a.samples.get("x").unwrap();
        assert_eq!(stage.exact.len(), EXACT_SAMPLE_CAP);
    }
}
