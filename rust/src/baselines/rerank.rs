//! Cross-encoder re-ranker proxies for the GPTCache baseline (§4.2.1 uses
//! `GPTCache/albert-duplicate-onnx` and
//! `cross-encoder/quora-distilroberta-base`).
//!
//! A cross-encoder reads *both* texts jointly and scores duplicate
//! likelihood; unlike the bi-encoder embedding it can catch polarity flips
//! — sometimes. The proxies score lexical-overlap evidence plus an
//! antonym-flip detector with model-specific reliability, reproducing the
//! Fig 2 behaviour: re-ranking buys precision at a recall cost, and the two
//! models trade off slightly differently.

use crate::datasets::vocabulary::{POLARITY, SYNONYMS};
use crate::tokenizer::Tokenizer;
use crate::util::rng::hash_bytes;

/// Function/template words a duplicate classifier learns to ignore.
const STOPWORDS: &[&str] = &[
    "a", "an", "the", "is", "are", "was", "be", "being", "been", "do", "does",
    "did", "can", "could", "should", "would", "will", "i", "you", "my", "me",
    "we", "it", "its", "this", "that", "these", "those", "of", "for", "to",
    "in", "on", "at", "with", "about", "as", "by", "from", "into", "than",
    "then", "and", "or", "but", "not", "no", "so", "up", "down", "out", "if",
    "when", "what", "which", "who", "how", "why", "where", "come", "comes",
    "make", "makes", "made", "get", "getting", "go", "going", "am", "pick",
    "place", "start", "new", "other", "most", "more", "any", "some", "just",
    "really", "please", "hey", "thanks", "advance", "appreciate", "help",
    "curious", "honest", "serious", "question", "quick", "wondering", "tell",
    "know", "?", "!", ".", ",",
    // template furniture (paraphrase-invariant wording a trained duplicate
    // classifier abstracts over; polarity flips are still caught by the
    // antonym detector, which reads the raw token sets)
    "way", "improve", "boost", "increase", "tips", "advice", "suggestions",
    "best", "ideal", "top", "better", "superior", "explain", "describe",
    "clarify", "options", "choices", "compared", "beginner", "learn",
    "understand", "good", "solid", "decent", "bad", "great", "terrible",
    "helpful", "harmful", "recommended", "discouraged", "effective",
    "ineffective", "things",
];

fn is_stopword(w: &str) -> bool {
    STOPWORDS.contains(&w)
}

/// Canonicalize a word to its synonym-group representative (what a trained
/// cross-encoder's representation does implicitly).
fn canonical(w: &str) -> &str {
    for group in SYNONYMS {
        if group.contains(&w) {
            return group[0];
        }
    }
    w
}

/// Multi-word synonyms ("how come" == "why") handled at text level.
fn normalize_text(text: &str) -> Vec<String> {
    let lowered = text.to_lowercase().replace("how come", "why");
    Tokenizer::words(&lowered)
        .into_iter()
        .map(|w| canonical(&w).to_string())
        .collect()
}

/// Content words (canonicalized, stopwords removed).
fn content_set(text: &str) -> std::collections::BTreeSet<String> {
    normalize_text(text)
        .into_iter()
        .filter(|w| !is_stopword(w))
        .collect()
}

/// A scored judgement from a cross-encoder-style duplicate classifier.
pub trait CrossEncoder: Send {
    fn name(&self) -> &'static str;

    /// Duplicate likelihood in [0, 1] for (query, candidate).
    fn score(&self, query: &str, candidate: &str) -> f64;
}

/// Shared lexical machinery.
fn word_set(text: &str) -> std::collections::BTreeSet<String> {
    Tokenizer::words(text).into_iter().collect()
}

fn jaccard(a: &std::collections::BTreeSet<String>, b: &std::collections::BTreeSet<String>) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let inter = a.intersection(b).count() as f64;
    let union = a.union(b).count() as f64;
    inter / union
}

/// Does the pair contain an antonym flip (e.g. "good" in one, "bad" in the
/// other)? Returns the flipped pair when present.
fn antonym_flip(a: &std::collections::BTreeSet<String>, b: &std::collections::BTreeSet<String>) -> bool {
    for pair in POLARITY {
        let (p, n) = (pair[0], pair[1]);
        if (a.contains(p) && b.contains(n)) || (a.contains(n) && b.contains(p)) {
            return true;
        }
    }
    false
}

/// Deterministic pseudo-random coin for "does this model notice the flip on
/// this particular pair" — stable across runs, varies across pairs.
fn pair_coin(query: &str, candidate: &str, salt: u64) -> f64 {
    let h = hash_bytes(format!("{query}\u{1}{candidate}\u{1}{salt}").as_bytes());
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Shared scoring core: lexical overlap evidence + content-word mismatch
/// detection + antonym-flip detection, with model-specific reliabilities.
/// A trained cross-encoder reads both texts jointly, so unlike the
/// bi-encoder it can notice "same template, different entity" — sometimes.
fn cross_encoder_score(
    query: &str,
    candidate: &str,
    overlap_exp: f64,
    mismatch_detection: f64,
    mismatch_penalty: f64,
    flip_detection: f64,
    jitter: f64,
    salt: u64,
) -> f64 {
    let (a, b) = (word_set(query), word_set(candidate));
    let (ca, cb) = (content_set(query), content_set(candidate));
    let mut s = jaccard(&a, &b).powf(overlap_exp);

    // content-word mismatches (entity/attribute swaps): each side's
    // exclusive content words are evidence of different intent
    let mismatches = ca.symmetric_difference(&cb).count();
    for m in 0..mismatches {
        if pair_coin(query, candidate, salt ^ (m as u64 + 1)) < mismatch_detection {
            s *= mismatch_penalty;
        }
    }

    // antonym polarity flips ("good" vs "bad") — the canonical killer
    if antonym_flip(&a, &b) && pair_coin(query, candidate, salt ^ 0xF11F) < flip_detection {
        s *= 0.2;
    }

    // mild pair-specific jitter (model idiosyncrasy)
    s * (1.0 - jitter + 2.0 * jitter * pair_coin(query, candidate, salt ^ 0x7777))
}

/// ALBERT-duplicate-style proxy: strong mismatch/flip detector, slightly
/// conservative overall.
pub struct AlbertLike {
    pub flip_detection_rate: f64,
    pub mismatch_detection_rate: f64,
}

impl Default for AlbertLike {
    fn default() -> Self {
        AlbertLike { flip_detection_rate: 0.80, mismatch_detection_rate: 0.58 }
    }
}

impl CrossEncoder for AlbertLike {
    fn name(&self) -> &'static str {
        "albert-duplicate-onnx(proxy)"
    }

    fn score(&self, query: &str, candidate: &str) -> f64 {
        cross_encoder_score(
            query,
            candidate,
            0.6,
            self.mismatch_detection_rate,
            0.40,
            self.flip_detection_rate,
            0.06,
            0xA1,
        )
    }
}

/// quora-distilroberta-style proxy: more recall-friendly, weaker detectors.
pub struct DistilRobertaLike {
    pub flip_detection_rate: f64,
    pub mismatch_detection_rate: f64,
}

impl Default for DistilRobertaLike {
    fn default() -> Self {
        DistilRobertaLike { flip_detection_rate: 0.68, mismatch_detection_rate: 0.55 }
    }
}

impl CrossEncoder for DistilRobertaLike {
    fn name(&self) -> &'static str {
        "quora-distilroberta-base(proxy)"
    }

    fn score(&self, query: &str, candidate: &str) -> f64 {
        cross_encoder_score(
            query,
            candidate,
            0.45,
            self.mismatch_detection_rate,
            0.50,
            self.flip_detection_rate,
            0.08,
            0xD1,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_scores_high() {
        let ce = AlbertLike::default();
        let s = ce.score("why is coffee good for health?", "why is coffee good for health?");
        assert!(s > 0.85, "s={s}");
    }

    #[test]
    fn disjoint_scores_low() {
        let ce = AlbertLike::default();
        let s = ce.score("why is coffee good?", "draft an email about travel");
        assert!(s < 0.3, "s={s}");
    }

    #[test]
    fn polarity_flip_usually_caught_by_albert() {
        let ce = AlbertLike::default();
        // average over many paraphrase pairs so the detection coin averages
        let mut penalized = 0;
        for i in 0..100 {
            let q = format!("why is coffee {i} good for health?");
            let c = format!("why is coffee {i} bad for health?");
            let flip = ce.score(&q, &c);
            let same = ce.score(&q, &q.replace("good", "good"));
            if flip < same * 0.5 {
                penalized += 1;
            }
        }
        assert!(penalized >= 65, "penalized={penalized}");
    }

    #[test]
    fn distilroberta_weaker_on_flips() {
        let a = AlbertLike::default();
        let d = DistilRobertaLike::default();
        let mut a_caught = 0;
        let mut d_caught = 0;
        for i in 0..200 {
            let q = format!("is running {i} helpful for recovery?");
            let c = format!("is running {i} harmful for recovery?");
            if a.score(&q, &c) < 0.4 {
                a_caught += 1;
            }
            if d.score(&q, &c) < 0.4 {
                d_caught += 1;
            }
        }
        assert!(a_caught > d_caught, "albert={a_caught} distil={d_caught}");
    }

    #[test]
    fn deterministic() {
        let ce = DistilRobertaLike::default();
        let s1 = ce.score("a b c", "a b d");
        let s2 = ce.score("a b c", "a b d");
        assert_eq!(s1, s2);
    }
}
