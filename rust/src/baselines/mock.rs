//! Mock language models for unit tests and quality-model-driven evals:
//! deterministic, artifact-free, and instrumented.

use std::time::Duration;

use anyhow::Result;

use crate::cost::TokenUsage;
use crate::llm::{LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use crate::tokenizer::Tokenizer;

/// Echo-style mock: responds with a deterministic transform of the prompt;
/// records every call.
///
/// The session API is honored step-wise: a generation takes `steps`
/// `advance()` units, each costing `step_delay` of wall time. The defaults
/// (1 step, zero delay) keep the mock instantaneous; scheduler tests and
/// the serving bench raise them to model a slow Big LLM whose decode can be
/// overtaken by interleaved tweak generations.
pub struct MockLlm {
    name: String,
    pub respond_calls: Vec<String>,
    pub tweak_calls: Vec<TweakPrompt>,
    /// Fixed number of output tokens to report.
    pub output_tokens: usize,
    /// `advance()` units per generation (>= 1).
    pub steps: usize,
    /// Wall time burned by each `advance()` unit.
    pub step_delay: Duration,
}

impl MockLlm {
    pub fn new(name: &str) -> MockLlm {
        MockLlm {
            name: name.to_string(),
            respond_calls: Vec::new(),
            tweak_calls: Vec::new(),
            output_tokens: 16,
            steps: 1,
            step_delay: Duration::ZERO,
        }
    }

    /// Builder-style pacing override: `steps` decode units of `step_delay`
    /// each per generation.
    pub fn with_pace(mut self, steps: usize, step_delay: Duration) -> MockLlm {
        self.steps = steps.max(1);
        self.step_delay = step_delay;
        self
    }

    fn fresh_response(&self, query: &str) -> LlmResponse {
        let input_tokens = Tokenizer::words(query).len();
        LlmResponse {
            text: format!("[{}-fresh] answer about: {}", self.name, query),
            usage: TokenUsage { input_tokens, output_tokens: self.output_tokens },
            prefill_micros: 0,
            decode_micros: 0,
        }
    }

    fn tweak_response(&self, prompt: &TweakPrompt) -> LlmResponse {
        let input_tokens = Tokenizer::words(&prompt.new_query).len()
            + Tokenizer::words(&prompt.cached_query).len()
            + Tokenizer::words(&prompt.cached_response).len();
        LlmResponse {
            text: format!(
                "[{}-tweaked] {} (basis: {})",
                self.name, prompt.new_query, prompt.cached_response
            ),
            usage: TokenUsage { input_tokens, output_tokens: self.output_tokens },
            prefill_micros: 0,
            decode_micros: 0,
        }
    }

    fn session(&self, resp: LlmResponse) -> Box<dyn LlmSession> {
        Box::new(MockSession {
            resp,
            remaining: self.steps.max(1),
            step_delay: self.step_delay,
        })
    }
}

/// Scripted session: the response text is fixed at `begin` time (the mock is
/// deterministic); `advance()` just paces it out.
struct MockSession {
    resp: LlmResponse,
    remaining: usize,
    step_delay: Duration,
}

impl LlmSession for MockSession {
    fn advance(&mut self) -> Result<bool> {
        if self.remaining > 0 {
            if !self.step_delay.is_zero() {
                std::thread::sleep(self.step_delay);
            }
            self.remaining -= 1;
        }
        Ok(self.remaining > 0)
    }

    fn is_done(&self) -> bool {
        self.remaining == 0
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        Ok(self.resp)
    }
}

impl LanguageModel for MockLlm {
    fn name(&self) -> &str {
        &self.name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        self.respond_calls.push(query.to_string());
        Ok(self.fresh_response(query))
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        self.tweak_calls.push(prompt.clone());
        Ok(self.tweak_response(prompt))
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        self.respond_calls.push(query.to_string());
        Ok(self.session(self.fresh_response(query)))
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        self.tweak_calls.push(prompt.clone());
        Ok(self.session(self.tweak_response(prompt)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_records_calls() {
        let mut m = MockLlm::new("big");
        m.respond("q1").unwrap();
        m.tweak(&TweakPrompt {
            new_query: "nq".into(),
            cached_query: "cq".into(),
            cached_response: "cr".into(),
        })
        .unwrap();
        assert_eq!(m.respond_calls, vec!["q1"]);
        assert_eq!(m.tweak_calls.len(), 1);
    }

    #[test]
    fn usage_counts_all_tweak_segments() {
        let mut m = MockLlm::new("small");
        let r = m
            .tweak(&TweakPrompt {
                new_query: "one two".into(),
                cached_query: "three".into(),
                cached_response: "four five six".into(),
            })
            .unwrap();
        assert_eq!(r.usage.input_tokens, 6);
    }

    #[test]
    fn session_paces_and_matches_blocking_text() {
        let mut m = MockLlm::new("big").with_pace(3, Duration::ZERO);
        let blocking = m.respond("what is a monad").unwrap();
        let mut s = m.begin_respond("what is a monad").unwrap();
        assert!(!s.is_done());
        assert!(s.advance().unwrap()); // 1/3
        assert!(s.advance().unwrap()); // 2/3
        assert!(!s.advance().unwrap()); // 3/3 -> done
        assert!(s.is_done());
        assert_eq!(s.finish().unwrap().text, blocking.text);
        assert_eq!(m.respond_calls.len(), 2); // both shapes recorded
    }
}
