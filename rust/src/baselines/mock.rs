//! Mock language models for unit tests and quality-model-driven evals:
//! deterministic, artifact-free, and instrumented.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cost::TokenUsage;
use crate::faults::FaultMode;
use crate::llm::{prompts, BatchDecodeStats, LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use crate::runtime::PrefixCacheStats;
use crate::tokenizer::Tokenizer;

/// Scripted fault plan: maps the 0-based call index (counted across
/// `respond`, `tweak`, and both `begin_*` shapes) to the fault injected on
/// that call. Lives *inside* the mock — unlike the runtime
/// [`crate::faults::FaultyLlm`] wrapper, whose shared switch a controller
/// flips in wall time — so chaos tests can script per-attempt behavior
/// ("fail the first try, succeed the retry") deterministically.
pub struct FaultPlan {
    script: Box<dyn Fn(usize) -> FaultMode + Send>,
}

impl FaultPlan {
    pub fn new(script: impl Fn(usize) -> FaultMode + Send + 'static) -> FaultPlan {
        FaultPlan { script: Box::new(script) }
    }

    /// Error the first `n` calls, then heal — the retry-path script.
    pub fn fail_first(n: usize) -> FaultPlan {
        FaultPlan::new(move |call| if call < n { FaultMode::Error } else { FaultMode::Healthy })
    }

    /// Error every call whose index falls in `[from, to)` — a scripted
    /// mid-run outage window.
    pub fn fail_between(from: usize, to: usize) -> FaultPlan {
        FaultPlan::new(move |call| {
            if (from..to).contains(&call) {
                FaultMode::Error
            } else {
                FaultMode::Healthy
            }
        })
    }

    fn mode(&self, call: usize) -> FaultMode {
        (self.script)(call)
    }
}

/// Echo-style mock: responds with a deterministic transform of the prompt;
/// records every call.
///
/// The session API is honored step-wise: a generation takes `steps`
/// `advance()` units, each costing `step_delay` of wall time. The defaults
/// (1 step, zero delay) keep the mock instantaneous; scheduler tests and
/// the serving bench raise them to model a slow Big LLM whose decode can be
/// overtaken by interleaved tweak generations.
pub struct MockLlm {
    name: String,
    pub respond_calls: Vec<String>,
    pub tweak_calls: Vec<TweakPrompt>,
    /// Fixed number of output tokens to report.
    pub output_tokens: usize,
    /// `advance()` units per generation (>= 1).
    pub steps: usize,
    /// Wall time burned by each `advance()` unit.
    pub step_delay: Duration,
    /// Collective-advance slot pool (`with_batch`): sessions claim slots and
    /// one "dispatch" per fairness round advances every live slot, paying
    /// `step_delay` ONCE per round instead of once per session — the mock
    /// twin of the substrate's batched decode, so the scheduler's batched
    /// path (and its O(1)-dispatch economics) is exercisable in CI.
    batch: Option<Arc<Mutex<MockPool>>>,
    /// Scripted faults by call index (`with_fault_plan`); `None` = healthy.
    faults: Option<FaultPlan>,
    /// Calls consumed by the fault plan so far.
    calls: usize,
    /// Prefix-reuse simulation for the tweak pathway (`with_prefix_reuse`);
    /// `None` = every tweak prefills cold.
    prefix: Option<Arc<Mutex<MockPrefixSim>>>,
    /// Wall time per *recomputed* prefill token on the tweak pathway —
    /// reuse shows up as tweaks that skip the restored tokens' pacing.
    prefill_token_delay: Duration,
}

/// Prompt budget the prefix simulation encodes against — mirrors the
/// substrate decoders' `max_prefill`.
const MOCK_MAX_PREFILL: usize = 192;

/// Nominal resident bytes per simulated snapshot, for `PrefixCacheStats`
/// parity — the small substrate model's packed state (139264 f32).
const MOCK_STATE_BYTES: usize = 139264 * 4;

/// `Send`-safe twin of `runtime::PrefixCache` for the mock tier: the same
/// chunk-boundary keying, first-writer-wins deepening, LRU eviction, and
/// counters, with the packed K/V snapshot replaced by a unit marker (the
/// mock doesn't decode, so reuse shows up as skipped per-token prefill
/// pacing rather than a restored state). The real cache is `Rc`-based and
/// single-threaded; mocks cross into the engine thread, hence the twin.
struct MockPrefixSim {
    /// Resume-capable chunk depths, ascending (mirror of
    /// `Generator::resume_chunks`).
    chunks: Vec<usize>,
    /// Entry budget (the mock analogue of `prefix_cache_bytes`).
    max_entries: usize,
    /// Literal prefix ids → LRU tick of the last touch.
    entries: HashMap<Vec<i32>, u64>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    saved_tokens: u64,
}

impl MockPrefixSim {
    fn new(chunks: &[usize], max_entries: usize) -> MockPrefixSim {
        MockPrefixSim {
            chunks: chunks.to_vec(),
            max_entries: max_entries.max(1),
            entries: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            saved_tokens: 0,
        }
    }

    /// One lookup+deepen cycle for a prompt of `len` tokens: returns how
    /// many leading tokens a resume would restore (0 = cold), and stores
    /// every coverable chunk deeper than the hit — exactly the snapshot
    /// policy of the real engine paths.
    fn probe(&mut self, ids: &[i32], len: usize) -> usize {
        self.tick += 1;
        let mut covered = 0;
        for &p in &self.chunks {
            if p < len && p > covered {
                if let Some(t) = self.entries.get_mut(&ids[..p]) {
                    *t = self.tick;
                    covered = p;
                }
            }
        }
        if covered > 0 {
            self.hits += 1;
            self.saved_tokens += covered as u64;
        } else {
            self.misses += 1;
        }
        for &p in &self.chunks {
            if p < len && p > covered {
                self.entries.entry(ids[..p].to_vec()).or_insert(self.tick);
            }
        }
        while self.entries.len() > self.max_entries {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, &t)| t)
                .map(|(k, _)| k.clone())
                .expect("over budget implies non-empty");
            self.entries.remove(&victim);
            self.evictions += 1;
        }
        covered
    }

    fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            saved_tokens: self.saved_tokens,
            entries: self.entries.len(),
            bytes: self.entries.len() * MOCK_STATE_BYTES,
        }
    }
}

/// Shared slot pool behind `MockLlm::with_batch`. Mirrors the credit
/// protocol of `runtime::BatchedDecode`: the first session of a sweep to
/// advance runs one collective round; its peers consume banked credits.
struct MockPool {
    slots: Vec<Option<MockSlot>>,
    /// Wall time per collective ROUND (not per slot).
    step_delay: Duration,
    dispatches: u64,
    active_slot_sum: u64,
}

struct MockSlot {
    remaining: usize,
    credits: u32,
}

impl MockPool {
    fn new(slots: usize, step_delay: Duration) -> MockPool {
        MockPool {
            slots: (0..slots.max(1)).map(|_| None).collect(),
            step_delay,
            dispatches: 0,
            active_slot_sum: 0,
        }
    }

    fn admit(&mut self, steps: usize) -> Option<usize> {
        let slot = self.slots.iter().position(|s| s.is_none())?;
        self.slots[slot] = Some(MockSlot { remaining: steps.max(1), credits: 0 });
        Some(slot)
    }

    fn is_done(&self, slot: usize) -> bool {
        match self.slots.get(slot).and_then(|s| s.as_ref()) {
            Some(s) => s.remaining == 0,
            None => true,
        }
    }

    fn advance(&mut self, slot: usize) -> bool {
        {
            let s = self.slots[slot].as_mut().expect("advance on a free mock slot");
            if s.remaining == 0 {
                return false;
            }
            if s.credits > 0 {
                s.credits -= 1;
                return s.remaining > 0;
            }
        }
        // Collective round: one paced "dispatch" advances every live slot.
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut n_active = 0u64;
        for s in self.slots.iter_mut().flatten() {
            if s.remaining > 0 {
                s.remaining -= 1;
                s.credits += 1;
                n_active += 1;
            }
        }
        self.dispatches += 1;
        self.active_slot_sum += n_active;
        let s = self.slots[slot].as_mut().expect("slot vanished mid-round");
        if s.credits > 0 {
            s.credits -= 1;
        }
        s.remaining > 0
    }

    fn release(&mut self, slot: usize) {
        if let Some(s) = self.slots.get_mut(slot) {
            *s = None;
        }
    }
}

/// Largest byte index `<= i` on a `char` boundary of `s` — proportional
/// text slicing must never cut a multi-byte char in half
/// (`str::floor_char_boundary` is still unstable).
fn floor_char_boundary(s: &str, i: usize) -> usize {
    let mut i = i.min(s.len());
    while !s.is_char_boundary(i) {
        i -= 1;
    }
    i
}

/// A claimed slot of the mock pool, behind the standard session protocol.
struct MockBatchedSession {
    pool: Arc<Mutex<MockPool>>,
    slot: Option<usize>,
    resp: LlmResponse,
    /// Decode units this generation takes in total (pacing denominator).
    total: usize,
    /// Bytes of `resp.text` already surfaced through `take_delta`.
    emitted: usize,
}

impl LlmSession for MockBatchedSession {
    fn advance(&mut self) -> Result<bool> {
        let slot = self.slot.expect("advance after finish");
        Ok(self.pool.lock().unwrap().advance(slot))
    }

    fn is_done(&self) -> bool {
        match self.slot {
            Some(slot) => self.pool.lock().unwrap().is_done(slot),
            None => true,
        }
    }

    fn take_delta(&mut self) -> String {
        let Some(slot) = self.slot else {
            return String::new();
        };
        let remaining = match self.pool.lock().unwrap().slots.get(slot).and_then(|s| s.as_ref()) {
            Some(s) => s.remaining,
            None => 0,
        };
        let done = self.total.saturating_sub(remaining);
        let target =
            floor_char_boundary(&self.resp.text, self.resp.text.len() * done / self.total.max(1));
        if target <= self.emitted {
            return String::new();
        }
        let delta = self.resp.text[self.emitted..target].to_string();
        self.emitted = target;
        delta
    }

    fn finish(mut self: Box<Self>) -> Result<LlmResponse> {
        if let Some(slot) = self.slot.take() {
            self.pool.lock().unwrap().release(slot);
        }
        // clone: `Drop` forbids moving fields out of `self`
        Ok(self.resp.clone())
    }
}

impl Drop for MockBatchedSession {
    fn drop(&mut self) {
        if let Some(slot) = self.slot.take() {
            self.pool.lock().unwrap().release(slot);
        }
    }
}

impl MockLlm {
    pub fn new(name: &str) -> MockLlm {
        MockLlm {
            name: name.to_string(),
            respond_calls: Vec::new(),
            tweak_calls: Vec::new(),
            output_tokens: 16,
            steps: 1,
            step_delay: Duration::ZERO,
            batch: None,
            faults: None,
            calls: 0,
            prefix: None,
            prefill_token_delay: Duration::ZERO,
        }
    }

    /// Builder-style pacing override: `steps` decode units of `step_delay`
    /// each per generation. Call before `with_batch` — the pool snapshots
    /// the round delay when it is built.
    pub fn with_pace(mut self, steps: usize, step_delay: Duration) -> MockLlm {
        self.steps = steps.max(1);
        self.step_delay = step_delay;
        self
    }

    /// Enable the collective-advance slot pool: up to `slots` sessions
    /// advance together, one `step_delay` per round regardless of how many
    /// ride it. Overflow sessions fall back to independent pacing, exactly
    /// like the substrate model.
    pub fn with_batch(mut self, slots: usize) -> MockLlm {
        self.batch = Some(Arc::new(Mutex::new(MockPool::new(slots, self.step_delay))));
        self
    }

    /// Attach a scripted [`FaultPlan`]; each `respond`/`tweak`/`begin_*`
    /// call consumes one plan index.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> MockLlm {
        self.faults = Some(plan);
        self
    }

    /// Simulate cross-request KV prefix reuse on the tweak pathway: tweak
    /// prompts are encoded with the real tokenizer's suffix-protected
    /// framing (the substrate's exact token layout), probed against a
    /// chunk-keyed LRU, and paced at `token_delay` per *recomputed* prefill
    /// token — so reuse-on vs reuse-off latency is measurable without
    /// compiled artifacts. `max_entries` bounds the simulated cache.
    pub fn with_prefix_reuse(
        mut self,
        chunks: &[usize],
        max_entries: usize,
        token_delay: Duration,
    ) -> MockLlm {
        self.prefix = Some(Arc::new(Mutex::new(MockPrefixSim::new(chunks, max_entries))));
        self.prefill_token_delay = token_delay;
        self
    }

    /// Consume one fault-plan index for the call being made right now.
    fn next_fault(&mut self) -> FaultMode {
        let call = self.calls;
        self.calls += 1;
        match &self.faults {
            Some(p) => p.mode(call),
            None => FaultMode::Healthy,
        }
    }

    /// Apply this call's scripted fault to a blocking-shape call.
    fn faulted_blocking(&mut self, resp: LlmResponse) -> Result<LlmResponse> {
        match self.next_fault() {
            FaultMode::Healthy => Ok(resp),
            FaultMode::Delay(d) => {
                std::thread::sleep(d);
                Ok(resp)
            }
            FaultMode::Error => bail!("injected fault: {} unavailable", self.name),
            FaultMode::Hang => {
                bail!("injected fault: {} hung (blocking call refused)", self.name)
            }
            FaultMode::FailAfterTokens(_) => {
                bail!("injected fault: {} failed mid-generation", self.name)
            }
        }
    }

    /// Apply this call's scripted fault to a session-shape call. `Hang`
    /// yields a session that paces forever (reaped only by a deadline or
    /// generation timeout); `FailAfterTokens(n)` a session that errors on
    /// its `n`-th `advance`.
    fn faulted_session(&mut self, resp: LlmResponse) -> Result<Box<dyn LlmSession>> {
        match self.next_fault() {
            FaultMode::Healthy => Ok(self.session(resp)),
            FaultMode::Delay(d) => {
                std::thread::sleep(d);
                Ok(self.session(resp))
            }
            FaultMode::Error => bail!("injected fault: {} unavailable", self.name),
            FaultMode::Hang => Ok(Box::new(MockSession {
                resp,
                remaining: usize::MAX,
                total: usize::MAX,
                emitted: 0,
                step_delay: Duration::from_millis(1),
                fail_after: None,
            })),
            FaultMode::FailAfterTokens(n) => Ok(Box::new(MockSession {
                resp,
                remaining: self.steps.max(1),
                total: self.steps.max(1),
                emitted: 0,
                step_delay: self.step_delay,
                fail_after: Some(n),
            })),
        }
    }

    fn fresh_response(&self, query: &str) -> LlmResponse {
        let input_tokens = Tokenizer::words(query).len();
        LlmResponse {
            text: format!("[{}-fresh] answer about: {}", self.name, query),
            usage: TokenUsage { input_tokens, output_tokens: self.output_tokens },
            restored_tokens: 0,
            prefill_micros: 0,
            decode_micros: 0,
        }
    }

    fn tweak_response(&self, prompt: &TweakPrompt) -> LlmResponse {
        let text = format!(
            "[{}-tweaked] {} (basis: {})",
            self.name, prompt.new_query, prompt.cached_response
        );
        // Prefix-reuse simulation: encode with the substrate's exact tweak
        // framing, probe the chunk-keyed LRU, and pace only the recomputed
        // tokens. The TEXT never depends on reuse — like the real engine,
        // where resumed prefill is bit-identical to cold.
        if let Some(sim) = &self.prefix {
            let tok = Tokenizer::new(8192);
            let head = tok.encode(prompts::TWEAK_TEMPLATE);
            let (ids, len) = tok.encode_prompt_suffixed(
                &head,
                &[&prompt.cached_query, &prompt.cached_response],
                &prompt.new_query,
                MOCK_MAX_PREFILL,
                prompts::TWEAK_SUFFIX_RESERVE,
            );
            let restored = sim.lock().unwrap().probe(&ids, len);
            let recomputed = len - restored;
            if !self.prefill_token_delay.is_zero() {
                std::thread::sleep(self.prefill_token_delay * recomputed as u32);
            }
            return LlmResponse {
                text,
                usage: TokenUsage { input_tokens: len, output_tokens: self.output_tokens },
                restored_tokens: restored,
                prefill_micros: (self.prefill_token_delay * recomputed as u32).as_micros(),
                decode_micros: 0,
            };
        }
        let input_tokens = Tokenizer::words(&prompt.new_query).len()
            + Tokenizer::words(&prompt.cached_query).len()
            + Tokenizer::words(&prompt.cached_response).len();
        LlmResponse {
            text,
            usage: TokenUsage { input_tokens, output_tokens: self.output_tokens },
            restored_tokens: 0,
            prefill_micros: 0,
            decode_micros: 0,
        }
    }

    fn session(&self, resp: LlmResponse) -> Box<dyn LlmSession> {
        if let Some(pool) = &self.batch {
            if let Some(slot) = pool.lock().unwrap().admit(self.steps) {
                return Box::new(MockBatchedSession {
                    pool: Arc::clone(pool),
                    slot: Some(slot),
                    resp,
                    total: self.steps.max(1),
                    emitted: 0,
                });
            }
            // pool full: overflow onto an independent per-session mock
        }
        Box::new(MockSession {
            resp,
            remaining: self.steps.max(1),
            total: self.steps.max(1),
            emitted: 0,
            step_delay: self.step_delay,
            fail_after: None,
        })
    }
}

/// Scripted session: the response text is fixed at `begin` time (the mock is
/// deterministic); `advance()` just paces it out.
struct MockSession {
    resp: LlmResponse,
    remaining: usize,
    /// Decode units this generation takes in total (pacing denominator for
    /// proportional `take_delta` slices).
    total: usize,
    /// Bytes of `resp.text` already surfaced through `take_delta`.
    emitted: usize,
    step_delay: Duration,
    /// Scripted mid-generation failure: error on the `advance` after this
    /// many successful ones (`FaultMode::FailAfterTokens`).
    fail_after: Option<usize>,
}

impl LlmSession for MockSession {
    fn advance(&mut self) -> Result<bool> {
        if let Some(n) = &mut self.fail_after {
            if *n == 0 {
                bail!("injected fault: mock failed mid-generation");
            }
            *n -= 1;
        }
        if self.remaining > 0 {
            if !self.step_delay.is_zero() {
                std::thread::sleep(self.step_delay);
            }
            self.remaining -= 1;
        }
        Ok(self.remaining > 0)
    }

    fn is_done(&self) -> bool {
        self.remaining == 0
    }

    fn take_delta(&mut self) -> String {
        let done = self.total.saturating_sub(self.remaining);
        let target =
            floor_char_boundary(&self.resp.text, self.resp.text.len() * done / self.total.max(1));
        if target <= self.emitted {
            return String::new();
        }
        let delta = self.resp.text[self.emitted..target].to_string();
        self.emitted = target;
        delta
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        Ok(self.resp)
    }
}

impl LanguageModel for MockLlm {
    fn name(&self) -> &str {
        &self.name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        self.respond_calls.push(query.to_string());
        let resp = self.fresh_response(query);
        self.faulted_blocking(resp)
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        self.tweak_calls.push(prompt.clone());
        let resp = self.tweak_response(prompt);
        self.faulted_blocking(resp)
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        self.respond_calls.push(query.to_string());
        let resp = self.fresh_response(query);
        self.faulted_session(resp)
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        self.tweak_calls.push(prompt.clone());
        let resp = self.tweak_response(prompt);
        self.faulted_session(resp)
    }

    fn batch_stats(&self) -> Option<BatchDecodeStats> {
        self.batch.as_ref().map(|pool| {
            let pool = pool.lock().unwrap();
            BatchDecodeStats {
                dispatches: pool.dispatches,
                active_slot_sum: pool.active_slot_sum,
                slots: pool.slots.len(),
            }
        })
    }

    fn prefix_stats(&self) -> Option<PrefixCacheStats> {
        self.prefix.as_ref().map(|sim| sim.lock().unwrap().stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_records_calls() {
        let mut m = MockLlm::new("big");
        m.respond("q1").unwrap();
        m.tweak(&TweakPrompt {
            new_query: "nq".into(),
            cached_query: "cq".into(),
            cached_response: "cr".into(),
        })
        .unwrap();
        assert_eq!(m.respond_calls, vec!["q1"]);
        assert_eq!(m.tweak_calls.len(), 1);
    }

    #[test]
    fn usage_counts_all_tweak_segments() {
        let mut m = MockLlm::new("small");
        let r = m
            .tweak(&TweakPrompt {
                new_query: "one two".into(),
                cached_query: "three".into(),
                cached_response: "four five six".into(),
            })
            .unwrap();
        assert_eq!(r.usage.input_tokens, 6);
    }

    #[test]
    fn batched_mock_sessions_advance_collectively() {
        let mut m = MockLlm::new("big").with_pace(4, Duration::ZERO).with_batch(2);
        let mut a = m.begin_respond("query a").unwrap();
        let mut b = m.begin_respond("query b").unwrap();
        // Round-robin like the scheduler: each sweep must cost ONE pool
        // dispatch for both sessions together.
        while !a.is_done() || !b.is_done() {
            if !a.is_done() {
                a.advance().unwrap();
            }
            if !b.is_done() {
                b.advance().unwrap();
            }
        }
        let stats = m.batch_stats().unwrap();
        assert_eq!(stats.dispatches, 4, "one dispatch per sweep, not per session");
        assert_eq!(stats.active_slot_sum, 8);
        assert_eq!(stats.slots, 2);
        let ra = a.finish().unwrap();
        assert!(ra.text.contains("big-fresh"));
        assert_eq!(ra.text, b.finish().unwrap().text.replace("query b", "query a"));
    }

    #[test]
    fn batched_mock_pool_overflow_and_reuse() {
        let mut m = MockLlm::new("big").with_pace(2, Duration::ZERO).with_batch(1);
        let mut a = m.begin_respond("one").unwrap();
        let mut b = m.begin_respond("two").unwrap(); // pool full → independent
        while b.advance().unwrap() {}
        assert_eq!(
            m.batch_stats().unwrap().dispatches,
            0,
            "overflow sessions must not dispatch the pool"
        );
        while a.advance().unwrap() {}
        assert_eq!(m.batch_stats().unwrap().dispatches, 2);
        a.finish().unwrap(); // frees the slot
        let mut c = m.begin_respond("three").unwrap();
        while c.advance().unwrap() {}
        assert_eq!(
            m.batch_stats().unwrap().dispatches,
            4,
            "freed slot must be reused by the pool"
        );
        drop(c); // dropping an unfinished batched session releases its slot
        let d = m.begin_respond("four").unwrap();
        assert!(!d.is_done());
    }

    #[test]
    fn fault_plan_scripts_calls_by_index() {
        let mut m = MockLlm::new("big").with_fault_plan(FaultPlan::fail_first(2));
        assert!(m.respond("a").unwrap_err().to_string().contains("injected fault"));
        assert!(m.begin_respond("b").is_err());
        let healed = m.respond("c").unwrap();
        assert!(healed.text.contains("big-fresh"));
        assert_eq!(m.respond_calls.len(), 3, "faulted calls are still recorded");
    }

    #[test]
    fn fail_after_tokens_errors_mid_generation() {
        let mut m = MockLlm::new("big")
            .with_pace(4, Duration::ZERO)
            .with_fault_plan(FaultPlan::new(|_| FaultMode::FailAfterTokens(2)));
        let mut s = m.begin_respond("q").unwrap();
        assert!(s.advance().unwrap());
        assert!(s.advance().unwrap());
        let err = s.advance().unwrap_err();
        assert!(err.to_string().contains("mid-generation"));
    }

    #[test]
    fn hang_session_never_finishes_on_its_own() {
        let mut m = MockLlm::new("small").with_fault_plan(FaultPlan::new(|_| FaultMode::Hang));
        let mut s = m.begin_respond("q").unwrap();
        for _ in 0..3 {
            assert!(s.advance().unwrap());
        }
        assert!(!s.is_done());
    }

    #[test]
    fn prefix_reuse_hits_after_seeding_and_preserves_text() {
        // Chunk 32 reaches past the static template into the cached fields,
        // so distinct cache entries key distinct prefixes.
        let p1 = TweakPrompt {
            new_query: "how fast is rust?".into(),
            cached_query: "what is rust?".into(),
            cached_response: "a systems language".into(),
        };
        let p2 = TweakPrompt { new_query: "is rust memory safe?".into(), ..p1.clone() };
        let mut on = MockLlm::new("small").with_prefix_reuse(&[32], 8, Duration::ZERO);
        let a = on.tweak(&p1).unwrap();
        assert_eq!(a.restored_tokens, 0, "first tweak against an entry is cold");
        let b = on.tweak(&p2).unwrap();
        assert_eq!(b.restored_tokens, 32, "same entry, new query: chunk-32 resume");
        assert!(b.usage.input_tokens > 32);
        // Reuse never changes the text — the mock twin of bit-identity.
        let mut off = MockLlm::new("small");
        assert_eq!(b.text, off.tweak(&p2).unwrap().text);
        let s = on.prefix_stats().unwrap();
        assert_eq!((s.hits, s.misses, s.saved_tokens), (1, 1, 32));
        assert!(off.prefix_stats().is_none());
    }

    #[test]
    fn prefix_sim_evicts_lru_under_entry_budget() {
        let mut m = MockLlm::new("small").with_prefix_reuse(&[32], 2, Duration::ZERO);
        let tp = |i: usize| TweakPrompt {
            new_query: "q".into(),
            cached_query: format!("cached question number {i}"),
            cached_response: format!("cached answer number {i} with several extra words"),
        };
        for i in 0..3 {
            m.tweak(&tp(i)).unwrap(); // 3 distinct entries through budget 2
        }
        let s = m.prefix_stats().unwrap();
        assert_eq!((s.entries, s.evictions), (2, 1));
        // The oldest entry was evicted: its prompt misses again (and
        // re-seeds, displacing the next-oldest)...
        assert_eq!(m.tweak(&tp(0)).unwrap().restored_tokens, 0);
        // ...while the most recently used entry still hits.
        assert_eq!(m.tweak(&tp(2)).unwrap().restored_tokens, 32);
    }

    #[test]
    fn session_deltas_concatenate_to_blocking_text() {
        // Per-session and batched-pool mocks both pace out the response
        // proportionally; the concatenated deltas must equal the blocking
        // text once the session completes.
        let mut m = MockLlm::new("big").with_pace(4, Duration::ZERO);
        let blocking = m.respond("stream me").unwrap();
        let mut s = m.begin_respond("stream me").unwrap();
        assert_eq!(s.take_delta(), "", "nothing decoded before the first advance");
        let mut out = String::new();
        loop {
            let more = s.advance().unwrap();
            out.push_str(&s.take_delta());
            if !more {
                break;
            }
        }
        assert_eq!(out, blocking.text);

        let mut m = MockLlm::new("big").with_pace(4, Duration::ZERO).with_batch(2);
        let blocking = m.respond("stream me too").unwrap();
        let mut s = m.begin_respond("stream me too").unwrap();
        let mut out = String::new();
        loop {
            let more = s.advance().unwrap();
            out.push_str(&s.take_delta());
            if !more {
                break;
            }
        }
        assert_eq!(out, blocking.text);
    }

    #[test]
    fn session_paces_and_matches_blocking_text() {
        let mut m = MockLlm::new("big").with_pace(3, Duration::ZERO);
        let blocking = m.respond("what is a monad").unwrap();
        let mut s = m.begin_respond("what is a monad").unwrap();
        assert!(!s.is_done());
        assert!(s.advance().unwrap()); // 1/3
        assert!(s.advance().unwrap()); // 2/3
        assert!(!s.advance().unwrap()); // 3/3 -> done
        assert!(s.is_done());
        assert_eq!(s.finish().unwrap().text, blocking.text);
        assert_eq!(m.respond_calls.len(), 2); // both shapes recorded
    }
}
