//! Mock language models for unit tests and quality-model-driven evals:
//! deterministic, artifact-free, and instrumented.

use anyhow::Result;

use crate::cost::TokenUsage;
use crate::llm::{LanguageModel, LlmResponse, TweakPrompt};
use crate::tokenizer::Tokenizer;

/// Echo-style mock: responds with a deterministic transform of the prompt;
/// records every call.
pub struct MockLlm {
    name: String,
    pub respond_calls: Vec<String>,
    pub tweak_calls: Vec<TweakPrompt>,
    /// Fixed number of output tokens to report.
    pub output_tokens: usize,
}

impl MockLlm {
    pub fn new(name: &str) -> MockLlm {
        MockLlm {
            name: name.to_string(),
            respond_calls: Vec::new(),
            tweak_calls: Vec::new(),
            output_tokens: 16,
        }
    }
}

impl LanguageModel for MockLlm {
    fn name(&self) -> &str {
        &self.name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        self.respond_calls.push(query.to_string());
        let input_tokens = Tokenizer::words(query).len();
        Ok(LlmResponse {
            text: format!("[{}-fresh] answer about: {}", self.name, query),
            usage: TokenUsage { input_tokens, output_tokens: self.output_tokens },
            prefill_micros: 0,
            decode_micros: 0,
        })
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        self.tweak_calls.push(prompt.clone());
        let input_tokens = Tokenizer::words(&prompt.new_query).len()
            + Tokenizer::words(&prompt.cached_query).len()
            + Tokenizer::words(&prompt.cached_response).len();
        Ok(LlmResponse {
            text: format!(
                "[{}-tweaked] {} (basis: {})",
                self.name, prompt.new_query, prompt.cached_response
            ),
            usage: TokenUsage { input_tokens, output_tokens: self.output_tokens },
            prefill_micros: 0,
            decode_micros: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_records_calls() {
        let mut m = MockLlm::new("big");
        m.respond("q1").unwrap();
        m.tweak(&TweakPrompt {
            new_query: "nq".into(),
            cached_query: "cq".into(),
            cached_response: "cr".into(),
        })
        .unwrap();
        assert_eq!(m.respond_calls, vec!["q1"]);
        assert_eq!(m.tweak_calls.len(), 1);
    }

    #[test]
    fn usage_counts_all_tweak_segments() {
        let mut m = MockLlm::new("small");
        let r = m
            .tweak(&TweakPrompt {
                new_query: "one two".into(),
                cached_query: "three".into(),
                cached_response: "four five six".into(),
            })
            .unwrap();
        assert_eq!(r.usage.input_tokens, 6);
    }
}
