//! Baselines the paper compares against (or that its evaluation needs):
//!
//! * `gptcache` — GPTCache-style verbatim semantic cache with cross-encoder
//!   re-ranking (Fig 2's subject, and §2's primary related work);
//! * `rerank` — the two cross-encoder proxies;
//! * `mock` — deterministic mock LLMs for tests and quality-model evals.
//!
//! The "no-cache" baseline (everything served by Big LLM) and the
//! "small-direct" control (Fig 6) need no machinery: they are the router
//! with the cache disabled / the Small LLM called directly.

pub mod gptcache;
pub mod mock;
pub mod rerank;

pub use gptcache::{GptCacheBaseline, GptCacheHit};
pub use mock::{FaultPlan, MockLlm};
pub use rerank::{AlbertLike, CrossEncoder, DistilRobertaLike};
