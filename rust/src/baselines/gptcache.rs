//! GPTCache-style baseline (Bang, 2023; paper §2, §4.2.1): single-layer
//! semantic cache that returns cached responses *verbatim* — no tweaking.
//!
//! put(): embed + insert. get(): ANN top-k by cosine above the vector-DB
//! threshold, then re-rank the candidates with a cross-encoder and return
//! the best match. This is the architecture Fig 2 sweeps.

use anyhow::Result;

use super::rerank::CrossEncoder;
use crate::cache::{FlatIndex, SearchHit, VectorIndex};
use crate::runtime::TextEmbedder;

pub struct GptCacheBaseline<'a> {
    embedder: &'a dyn TextEmbedder,
    rerank: Box<dyn CrossEncoder>,
    /// Vector-DB retrieval threshold (the swept knob in Fig 2).
    pub ann_threshold: f32,
    /// Candidates fetched before re-ranking.
    pub top_k: usize,
    /// Final accept threshold on the cross-encoder score.
    pub rerank_threshold: f64,
    index: FlatIndex,
    queries: Vec<String>,
    responses: Vec<String>,
}

/// A returned cache hit.
#[derive(Clone, Debug)]
pub struct GptCacheHit {
    pub id: usize,
    pub cached_query: String,
    pub cached_response: String,
    pub cosine: f32,
    pub rerank_score: f64,
}

impl<'a> GptCacheBaseline<'a> {
    pub fn new(
        embedder: &'a dyn TextEmbedder,
        rerank: Box<dyn CrossEncoder>,
        ann_threshold: f32,
    ) -> Self {
        GptCacheBaseline {
            index: FlatIndex::new(embedder.out_dim()),
            embedder,
            rerank,
            ann_threshold,
            top_k: 4,
            rerank_threshold: 0.55,
            queries: Vec::new(),
            responses: Vec::new(),
        }
    }

    /// put(): store (query, response).
    pub fn put(&mut self, query: &str, response: &str) -> Result<()> {
        let e = self.embedder.embed(query)?;
        self.index.insert(&e);
        self.queries.push(query.to_string());
        self.responses.push(response.to_string());
        Ok(())
    }

    /// Bulk put with batched embedding.
    pub fn put_batch(&mut self, pairs: &[(String, String)]) -> Result<()> {
        let qs: Vec<&str> = pairs.iter().map(|(q, _)| q.as_str()).collect();
        let es = self.embedder.embed_batch(&qs)?;
        for ((q, r), e) in pairs.iter().zip(es) {
            self.index.insert(&e);
            self.queries.push(q.clone());
            self.responses.push(r.clone());
        }
        Ok(())
    }

    /// get(): retrieve the best cached response for `query`, if any
    /// candidate clears both thresholds.
    pub fn get(&self, query: &str) -> Result<Option<GptCacheHit>> {
        let e = self.embedder.embed(query)?;
        self.get_embedded(query, &e)
    }

    pub fn get_embedded(&self, query: &str, embedding: &[f32]) -> Result<Option<GptCacheHit>> {
        let hits: Vec<SearchHit> = self
            .index
            .search(embedding, self.top_k)
            .into_iter()
            .filter(|h| h.score >= self.ann_threshold)
            .collect();
        if hits.is_empty() {
            return Ok(None);
        }
        // Re-rank the candidates with the cross-encoder.
        let mut best: Option<GptCacheHit> = None;
        for h in hits {
            let s = self.rerank.score(query, &self.queries[h.id]);
            if best.as_ref().map(|b| s > b.rerank_score).unwrap_or(true) {
                best = Some(GptCacheHit {
                    id: h.id,
                    cached_query: self.queries[h.id].clone(),
                    cached_response: self.responses[h.id].clone(),
                    cosine: h.score,
                    rerank_score: s,
                });
            }
        }
        Ok(best.filter(|b| b.rerank_score >= self.rerank_threshold))
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }
}
