//! Cache-hit distribution study (paper §4.2.3, Figs 8–9) and the §5.2.3
//! cost analysis.
//!
//! Protocol: insert the first half of a trace into the vector DB, query the
//! second half, and record the top-1 cosine similarity of every query. The
//! hit rate at threshold τ is the fraction of queries with similarity ≥ τ;
//! the cost saving follows from the hit rate and the per-token price ratio.

use anyhow::Result;

use crate::cache::{FlatIndex, VectorIndex};
use crate::cost::analytic_cost_ratio;
use crate::datasets::QueryRecord;
use crate::runtime::TextEmbedder;

/// Result of one half-insert/half-query run.
#[derive(Clone, Debug)]
pub struct HitRateCurve {
    /// Top-1 similarity per queried item (NaN-free; empty-cache → -1).
    pub similarities: Vec<f32>,
    pub inserted: usize,
    pub queried: usize,
}

impl HitRateCurve {
    pub fn hit_rate_at(&self, threshold: f32) -> f64 {
        if self.similarities.is_empty() {
            return 0.0;
        }
        let hits = self.similarities.iter().filter(|s| **s >= threshold).count();
        hits as f64 / self.similarities.len() as f64
    }

    /// The Figs 8–9 histogram: bucket counts over [lo, 1.0].
    pub fn histogram(&self, lo: f32, buckets: usize) -> Vec<(f32, f32, usize)> {
        let width = (1.0 - lo) / buckets as f32;
        let mut out: Vec<(f32, f32, usize)> = (0..buckets)
            .map(|i| (lo + i as f32 * width, lo + (i + 1) as f32 * width, 0))
            .collect();
        for &s in &self.similarities {
            if s < lo {
                continue;
            }
            let idx = (((s - lo) / width) as usize).min(buckets - 1);
            out[idx].2 += 1;
        }
        out
    }

    /// §5.2.3: fraction of original (all-Big) cost when hits above τ go to
    /// the small pathway.
    pub fn cost_ratio(&self, threshold: f32, price_ratio: f64) -> f64 {
        analytic_cost_ratio(self.hit_rate_at(threshold), price_ratio)
    }
}

/// Run the protocol with batched embedding.
pub fn run(
    insert: &[QueryRecord],
    query: &[QueryRecord],
    embedder: &dyn TextEmbedder,
) -> Result<HitRateCurve> {
    let mut index = FlatIndex::new(embedder.out_dim());
    let insert_texts: Vec<&str> = insert.iter().map(|q| q.text.as_str()).collect();
    for e in embedder.embed_batch(&insert_texts)? {
        index.insert(&e);
    }
    let query_texts: Vec<&str> = query.iter().map(|q| q.text.as_str()).collect();
    let mut similarities = Vec::with_capacity(query.len());
    for e in embedder.embed_batch(&query_texts)? {
        let top = index.search(&e, 1);
        similarities.push(top.first().map(|h| h.score).unwrap_or(-1.0));
    }
    Ok(HitRateCurve { similarities, inserted: insert.len(), queried: query.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{ChatTrace, TraceProfile};
    use crate::runtime::NativeBowEmbedder;

    fn curve(profile: TraceProfile, n: usize, seed: u64) -> HitRateCurve {
        let t = ChatTrace::generate(profile, n, seed);
        let (a, b) = t.halves();
        let emb = NativeBowEmbedder::new(96, 3);
        run(a, b, &emb).unwrap()
    }

    #[test]
    fn lmsys_hits_more_than_wildchat() {
        // the Fig 8 vs Fig 9 headline: 68% vs 40% at τ=0.8
        let l = curve(TraceProfile::lmsys(), 3000, 1);
        let w = curve(TraceProfile::wildchat(), 3000, 1);
        let (hl, hw) = (l.hit_rate_at(0.8), w.hit_rate_at(0.8));
        assert!(hl > hw + 0.1, "lmsys={hl} wildchat={hw}");
    }

    #[test]
    fn hit_rate_monotone_in_threshold() {
        let c = curve(TraceProfile::lmsys(), 2000, 2);
        let mut prev = 1.1;
        for t in [0.5, 0.6, 0.7, 0.8, 0.9, 0.99] {
            let h = c.hit_rate_at(t);
            assert!(h <= prev + 1e-9);
            prev = h;
        }
    }

    #[test]
    fn histogram_sums_to_inrange() {
        let c = curve(TraceProfile::wildchat(), 1000, 3);
        let hist = c.histogram(0.0, 20);
        let total: usize = hist.iter().map(|(_, _, n)| n).sum();
        let inrange = c.similarities.iter().filter(|s| **s >= 0.0).count();
        assert_eq!(total, inrange);
    }

    #[test]
    fn cost_ratio_sane() {
        let c = curve(TraceProfile::lmsys(), 2000, 4);
        let r = c.cost_ratio(0.8, 25.0);
        assert!(r > 0.0 && r < 1.0, "r={r}");
    }
}
