//! Traditional-semantic-caching study (paper §4.2.1, Fig 2).
//!
//! Protocol, mirrored exactly: for each labeled pair, `put()` the first
//! question, then `get()` the second through the GPTCache baseline (ANN
//! retrieval at the swept cosine threshold + cross-encoder re-rank), then
//! `put()` the second question too so the cache grows over time.
//!
//! * TP — cache hit on a human-labeled duplicate pair
//! * FP — cache hit on a non-duplicate (would serve a wrong answer)
//! * FN — cache miss on a duplicate (missed saving)

use anyhow::Result;

use crate::baselines::{CrossEncoder, GptCacheBaseline};
use crate::datasets::{ideal_response, intent_affinity, LabeledPair};
use crate::runtime::TextEmbedder;

#[derive(Clone, Copy, Debug, Default)]
pub struct PrCounts {
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
    pub tn: u64,
}

impl PrCounts {
    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return f64::NAN;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return f64::NAN;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }
}

/// One sweep point.
#[derive(Clone, Debug)]
pub struct PrPoint {
    pub threshold: f32,
    pub counts: PrCounts,
    pub hits: u64,
}

/// Run the §4.2.1 protocol at one ANN threshold.
///
/// Note the subtlety the paper's protocol has: the cache also contains
/// *other* pairs' questions, so a `get(q2)` may hit a stored question from
/// a different pair. We score such cross-pair hits by intent ground truth
/// (duplicate iff intents match), which is exactly what the human labels
/// encode for in-pair hits.
pub fn run_at_threshold(
    pairs: &[LabeledPair],
    embedder: &dyn TextEmbedder,
    rerank: Box<dyn CrossEncoder>,
    threshold: f32,
) -> Result<PrPoint> {
    let mut cache = GptCacheBaseline::new(embedder, rerank, threshold);
    // Pre-embed every question in batch (the big win on the compiled path).
    let mut counts = PrCounts::default();
    let mut hits = 0u64;

    // intent lookup for every stored question, aligned with insertion order
    let mut stored_intents = Vec::with_capacity(pairs.len() * 2);

    for pair in pairs {
        // put(q1)
        cache.put(&pair.q1.text, &ideal_response(&pair.q1.intent))?;
        stored_intents.push(pair.q1.intent);

        // get(q2)
        let hit = cache.get(&pair.q2.text)?;
        let is_dup_hit = match &hit {
            Some(h) => {
                hits += 1;
                let cached_intent = stored_intents[h.id];
                // ground truth: served content answers the query iff the
                // intents match (affinity 1.0)
                intent_affinity(&cached_intent, &pair.q2.intent) >= 1.0
            }
            None => false,
        };
        match (hit.is_some(), pair.is_duplicate, is_dup_hit) {
            (true, _, true) => counts.tp += 1,
            (true, _, false) => counts.fp += 1,
            (false, true, _) => counts.fn_ += 1,
            (false, false, _) => counts.tn += 1,
        }

        // put(q2): "enabling growth of the cache over time"
        cache.put(&pair.q2.text, &ideal_response(&pair.q2.intent))?;
        stored_intents.push(pair.q2.intent);
    }

    Ok(PrPoint { threshold, counts, hits })
}

/// Full Fig 2 sweep.
pub fn sweep<F>(
    pairs: &[LabeledPair],
    embedder: &dyn TextEmbedder,
    make_rerank: F,
    thresholds: &[f32],
) -> Result<Vec<PrPoint>>
where
    F: Fn() -> Box<dyn CrossEncoder>,
{
    thresholds
        .iter()
        .map(|t| run_at_threshold(pairs, embedder, make_rerank(), *t))
        .collect()
}

/// The paper's sweep grid (0.70 → 0.99).
pub fn paper_thresholds() -> Vec<f32> {
    let mut ts: Vec<f32> = (0..=9)
        .map(|i| 0.70 + i as f32 * 0.03)
        .chain([0.99])
        .map(|t| (t * 100.0).round() / 100.0)
        .filter(|t| *t <= 0.99)
        .collect();
    ts.dedup();
    ts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::AlbertLike;
    use crate::datasets::QuestionPairDataset;
    use crate::runtime::NativeBowEmbedder;

    #[test]
    fn counts_math() {
        let c = PrCounts { tp: 9, fp: 1, fn_: 10, tn: 80 };
        assert!((c.precision() - 0.9).abs() < 1e-9);
        assert!((c.recall() - 9.0 / 19.0).abs() < 1e-9);
    }

    #[test]
    fn tradeoff_shape_holds() {
        // precision rises and recall falls as the threshold tightens —
        // the qualitative content of Fig 2.
        let ds = QuestionPairDataset::generate(300, 11);
        let emb = NativeBowEmbedder::new(96, 5);
        let lo = run_at_threshold(&ds.pairs, &emb, Box::new(AlbertLike::default()), 0.70)
            .unwrap();
        let hi = run_at_threshold(&ds.pairs, &emb, Box::new(AlbertLike::default()), 0.95)
            .unwrap();
        assert!(hi.counts.precision() >= lo.counts.precision() - 0.02,
            "precision lo={} hi={}", lo.counts.precision(), hi.counts.precision());
        assert!(hi.counts.recall() < lo.counts.recall(),
            "recall lo={} hi={}", lo.counts.recall(), hi.counts.recall());
        assert!(lo.counts.precision() < 1.0, "low threshold must admit FPs");
    }

    #[test]
    fn paper_grid_bounds() {
        let ts = paper_thresholds();
        assert!(ts.first().unwrap() - 0.70 < 1e-6);
        assert!(*ts.last().unwrap() <= 0.99 + 1e-6);
        assert!(ts.len() >= 8);
    }
}
