//! Calibrated response-quality model.
//!
//! The substrate LLMs carry random weights, so generated token streams are
//! not semantically gradeable. What the paper's verdict figures (3–7)
//! actually measure is the *distribution of response quality per pathway as
//! a function of prompt similarity*. We model that distribution explicitly
//! — grounded in the dataset's construction-time intent metadata — and let
//! every judge (simulated survey respondents, debate personas) observe it
//! through noise. The real token path still produces the responses, drives
//! all latency/cost numbers, and supplies the cache content.
//!
//! Model:
//! * Big-direct quality ~ high baseline (frontier model).
//! * Small-direct quality ~ strictly lower (Fig 6's control).
//! * Small-tweaked quality = Big baseline × tweak effectiveness, where the
//!   effectiveness grows with the *intent affinity* between the new query
//!   and the cached query (surface cosine similarity is its noisy proxy).
//!   At affinity → 1 the tweak is a light edit of a frontier response and
//!   can even beat a fresh Big generation (the paper observes exactly this
//!   in the 0.9–1.0 band: 82.6% vs 77.4% satisfaction); at affinity ~0.7 the
//!   Small model must rewrite substantially and quality dips below Big.

use crate::datasets::{intent_affinity, IntentKey};
use crate::util::Rng;

/// Three facets, matching the debate personas (Table 2).
#[derive(Clone, Copy, Debug)]
pub struct ResponseQuality {
    pub factual: f64,
    pub ux: f64,
    pub relevance: f64,
}

impl ResponseQuality {
    pub fn mean(&self) -> f64 {
        (self.factual + self.ux + self.relevance) / 3.0
    }

    fn clamped(f: f64, u: f64, r: f64) -> ResponseQuality {
        ResponseQuality {
            factual: f.clamp(0.0, 1.0),
            ux: u.clamp(0.0, 1.0),
            relevance: r.clamp(0.0, 1.0),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseKind {
    BigDirect,
    SmallDirect,
    /// Tweaked from a cached response; carries the cosine similarity between
    /// the new and cached queries.
    SmallTweaked,
}

/// Calibration constants (exposed for the ablation bench).
#[derive(Clone, Copy, Debug)]
pub struct QualityParams {
    pub big_mean: f64,
    pub big_std: f64,
    pub small_mean: f64,
    pub small_std: f64,
    /// Tweak effectiveness at affinity 0.7 and at affinity 1.0 (linear in
    /// between, clamped outside).
    pub tweak_eff_at_07: f64,
    pub tweak_eff_at_10: f64,
    pub tweak_std: f64,
}

impl Default for QualityParams {
    fn default() -> Self {
        QualityParams {
            big_mean: 0.80,
            big_std: 0.09,
            small_mean: 0.645,
            small_std: 0.11,
            tweak_eff_at_07: 0.885,
            tweak_eff_at_10: 0.905,
            tweak_std: 0.10,
        }
    }
}

pub struct QualityModel {
    pub params: QualityParams,
    rng: Rng,
}

impl QualityModel {
    pub fn new(seed: u64) -> QualityModel {
        QualityModel { params: QualityParams::default(), rng: Rng::substream(seed, "quality") }
    }

    pub fn with_params(seed: u64, params: QualityParams) -> QualityModel {
        QualityModel { params, rng: Rng::substream(seed, "quality") }
    }

    /// Quality of a Big-LLM direct generation for a query.
    pub fn big_direct(&mut self) -> ResponseQuality {
        let p = self.params;
        ResponseQuality::clamped(
            self.rng.normal_ms(p.big_mean, p.big_std),
            self.rng.normal_ms(p.big_mean, p.big_std),
            self.rng.normal_ms(p.big_mean + 0.02, p.big_std),
        )
    }

    /// Quality of a Small-LLM direct generation (no cache, no tweak).
    pub fn small_direct(&mut self) -> ResponseQuality {
        let p = self.params;
        ResponseQuality::clamped(
            self.rng.normal_ms(p.small_mean, p.small_std),
            self.rng.normal_ms(p.small_mean + 0.04, p.small_std),
            self.rng.normal_ms(p.small_mean, p.small_std),
        )
    }

    /// Tweak effectiveness multiplier at a given effective affinity.
    /// Linear between the two calibration anchors above 0.7; below 0.7 the
    /// cached content is an increasingly poor basis and effectiveness decays
    /// toward a floor (the Small LLM rewriting mostly from scratch).
    pub fn tweak_effectiveness(&self, affinity: f64) -> f64 {
        let p = self.params;
        if affinity < 0.7 {
            // Nearly flat: the Appendix-A prompt tells the Small LLM to
            // ignore a poor basis, so effectiveness barely decays with
            // affinity here — the instruction does the heavy lifting.
            let t = ((affinity - 0.45) / 0.25).clamp(0.0, 1.0);
            return 0.865 + t * (p.tweak_eff_at_07 - 0.865);
        }
        let t = ((affinity - 0.7) / 0.3).clamp(0.0, 1.0);
        p.tweak_eff_at_07 + t * (p.tweak_eff_at_10 - p.tweak_eff_at_07)
    }

    /// Quality of a Small-LLM *tweaked* response.
    ///
    /// `similarity` is the observed cosine between new and cached queries;
    /// `intents` (when the harness has ground truth) sharpens the affinity
    /// estimate — a polarity-flip pair can show cosine 0.9 but affinity 0.2,
    /// and the tweak must then rewrite almost from scratch, landing between
    /// small-direct and big-direct.
    pub fn small_tweaked(
        &mut self,
        similarity: f32,
        intents: Option<(&IntentKey, &IntentKey)>,
    ) -> ResponseQuality {
        let p = self.params;
        let affinity = match intents {
            Some((a, b)) => 0.5 * similarity as f64 + 0.5 * intent_affinity(a, b),
            None => similarity as f64,
        };
        if affinity < 0.45 {
            // Cached content is actively unrelated/misleading: the tweak
            // prompt tells the Small LLM to ignore it ("you need not
            // constrain yourself closely"), so quality ≈ small-direct with
            // a small penalty for the distraction.
            let q = self.small_direct();
            return ResponseQuality::clamped(
                q.factual - 0.03,
                q.ux,
                q.relevance - 0.05,
            );
        }
        let eff = self.tweak_effectiveness(affinity);
        let base = p.big_mean * eff;
        // UX rises faster than factuality with affinity: a light edit of a
        // frontier answer reads *better* than a fresh generation (concise,
        // already-polished prose), even while expert judges still find
        // factual/completeness gaps. This is exactly the paper's Fig 3 vs
        // Fig 5 split: users rate tweaked >= big in the top band while the
        // debate still leans Big.
        let t = ((affinity - 0.45) / 0.55).clamp(0.0, 1.0);
        ResponseQuality::clamped(
            self.rng.normal_ms(base, p.tweak_std),
            self.rng.normal_ms(base + 0.15 * t, p.tweak_std),
            self.rng.normal_ms(base - 0.01 + 0.04 * (affinity - 0.7), p.tweak_std),
        )
    }

    pub fn quality_of(
        &mut self,
        kind: ResponseKind,
        similarity: f32,
        intents: Option<(&IntentKey, &IntentKey)>,
    ) -> ResponseQuality {
        match kind {
            ResponseKind::BigDirect => self.big_direct(),
            ResponseKind::SmallDirect => self.small_direct(),
            ResponseKind::SmallTweaked => self.small_tweaked(similarity, intents),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_of<F: FnMut(&mut QualityModel) -> ResponseQuality>(
        n: usize,
        mut f: F,
    ) -> f64 {
        let mut m = QualityModel::new(1);
        (0..n).map(|_| f(&mut m).mean()).sum::<f64>() / n as f64
    }

    #[test]
    fn big_beats_small_direct() {
        let big = mean_of(2000, |m| m.big_direct());
        let small = mean_of(2000, |m| m.small_direct());
        assert!(big > small + 0.10, "big={big} small={small}");
    }

    #[test]
    fn tweaked_improves_with_similarity() {
        let lo = mean_of(2000, |m| m.small_tweaked(0.72, None));
        let mid = mean_of(2000, |m| m.small_tweaked(0.85, None));
        let hi = mean_of(2000, |m| m.small_tweaked(0.97, None));
        assert!(lo < mid && mid < hi, "{lo} {mid} {hi}");
    }

    #[test]
    fn tweaked_at_high_sim_rivals_big() {
        let big = mean_of(4000, |m| m.big_direct());
        let hi = mean_of(4000, |m| m.small_tweaked(0.96, None));
        assert!((hi - big).abs() < 0.06, "hi={hi} big={big}");
    }

    #[test]
    fn polarity_flip_degrades_despite_high_cosine() {
        use crate::datasets::IntentKey;
        let a = IntentKey { domain: 1, entity: 2, attribute: 3, polarity: 0, class: 0, variant: 0 };
        let b = IntentKey { polarity: 1, ..a };
        let flipped = mean_of(2000, |m| m.small_tweaked(0.92, Some((&a, &b))));
        let true_dup = mean_of(2000, |m| m.small_tweaked(0.92, Some((&a, &a))));
        // the tweak *resolves* the flip (paper par.6) so quality stays
        // serviceable -- but strictly below a true-duplicate basis
        assert!(flipped < true_dup - 0.03, "flipped={flipped} dup={true_dup}");
        assert!(flipped > 0.60, "flip must remain resolvable: {flipped}");
    }

    #[test]
    fn qualities_are_bounded() {
        let mut m = QualityModel::new(3);
        for _ in 0..500 {
            for q in [
                m.big_direct(),
                m.small_direct(),
                m.small_tweaked(0.8, None),
            ] {
                assert!(q.factual >= 0.0 && q.factual <= 1.0);
                assert!(q.ux >= 0.0 && q.ux <= 1.0);
                assert!(q.relevance >= 0.0 && q.relevance <= 1.0);
            }
        }
    }
}
