//! Evaluation harnesses: everything in the paper's §4.2/§5.
//!
//! * `quality` — the calibrated response-quality model (the stand-in for
//!   "what would GPT-4o / Llama-8B / a tweaked response actually read
//!   like"; see DESIGN.md "Substitutions").
//! * `survey` — simulated user study (Figs 3–4).
//! * `debate` — multi-agent LLM-as-evaluator debate (Figs 5–7).
//! * `precision_recall` — traditional semantic caching study (Fig 2).
//! * `hit_rate` — cache-hit CDFs + cost analysis (Figs 8–9, §5.2.3).

pub mod debate;
pub mod hit_rate;
pub mod precision_recall;
pub mod quality;
pub mod survey;

pub use quality::{QualityModel, ResponseKind, ResponseQuality};

/// The cosine-similarity bands the paper reports (0.7–0.8, 0.8–0.9,
/// 0.9–1.0).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Band {
    B70,
    B80,
    B90,
}

impl Band {
    pub const ALL: [Band; 3] = [Band::B70, Band::B80, Band::B90];

    pub fn of(similarity: f32) -> Option<Band> {
        if similarity >= 0.9 {
            Some(Band::B90)
        } else if similarity >= 0.8 {
            Some(Band::B80)
        } else if similarity >= 0.7 {
            Some(Band::B70)
        } else {
            None
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Band::B70 => "0.7-0.8",
            Band::B80 => "0.8-0.9",
            Band::B90 => "0.9-1.0",
        }
    }

    /// Band midpoint (for the quality model's similarity input when only
    /// the band is known).
    pub fn midpoint(&self) -> f32 {
        match self {
            Band::B70 => 0.75,
            Band::B80 => 0.85,
            Band::B90 => 0.95,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banding() {
        assert_eq!(Band::of(0.95), Some(Band::B90));
        assert_eq!(Band::of(0.9), Some(Band::B90));
        assert_eq!(Band::of(0.85), Some(Band::B80));
        assert_eq!(Band::of(0.72), Some(Band::B70));
        assert_eq!(Band::of(0.69), None);
    }

    #[test]
    fn labels() {
        assert_eq!(Band::B70.label(), "0.7-0.8");
        assert_eq!(Band::B90.label(), "0.9-1.0");
    }
}
