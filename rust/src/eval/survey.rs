//! Simulated user study (paper §4.2.2 "User survey", results §5.2.1,
//! Figs 3–4).
//!
//! Protocol mirrored from the paper:
//! * 120 queries, 40 per cosine band (0.7–0.8 / 0.8–0.9 / 0.9–1.0);
//! * each respondent answers 3 side-by-side comparisons (Big vs Tweaked,
//!   blinded, shuffled; "prefer A" / "prefer B" / "both equally") and 6
//!   individual satisfaction ratings (binary), 3 per model;
//! * queries are assigned to respondents least-voted-first, mirroring the
//!   paper's even-distribution strategy;
//! * 194 collected responses, under-45-second responses excluded → 175
//!   valid, which we simulate directly as 175 valid respondents.
//!
//! Each simulated respondent has a leniency bias and decision noise;
//! satisfaction is a threshold vote on perceived quality; side-by-side is a
//! noisy comparison with a per-respondent draw margin.

use super::quality::ResponseQuality;
use super::Band;
use crate::util::Rng;

/// A survey item: one query that fell in `band` with the two responses'
/// latent qualities.
#[derive(Clone, Debug)]
pub struct SurveyItem {
    pub band: Band,
    pub big: ResponseQuality,
    pub tweaked: ResponseQuality,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SatisfactionCell {
    pub satisfied: u64,
    pub total: u64,
}

impl SatisfactionCell {
    pub fn rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.satisfied as f64 / self.total as f64 * 100.0
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SideBySideCell {
    pub big: u64,
    pub small: u64,
    pub draw: u64,
}

impl SideBySideCell {
    pub fn total(&self) -> u64 {
        self.big + self.small + self.draw
    }
}

/// Figure 3 + Figure 4 data.
#[derive(Clone, Debug, Default)]
pub struct SurveyResult {
    /// Satisfaction per band per model: (big, tweaked).
    pub satisfaction: Vec<(Band, SatisfactionCell, SatisfactionCell)>,
    /// Side-by-side votes per band.
    pub side_by_side: Vec<(Band, SideBySideCell)>,
    pub respondents: usize,
    pub excluded: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct SurveyConfig {
    pub n_respondents_collected: usize,
    /// Fraction discarded by the minimum-time filter (paper: 19/194).
    pub exclusion_rate: f64,
    pub side_by_side_per_respondent: usize,
    pub satisfaction_per_respondent: usize,
    /// Satisfaction response curve: P(satisfied) = base + slope*(judged - pivot),
    /// clamped to [0,1]. Lay users rate most competent answers satisfactory;
    /// quality moves the rate gently (the paper's Fig 3 is flat, 73-83%).
    pub satisfaction_base: f64,
    pub satisfaction_slope: f64,
    pub satisfaction_pivot: f64,
    /// Std of respondent leniency bias.
    pub bias_std: f64,
    /// Std of per-vote perception noise.
    pub noise_std: f64,
    /// Mean draw margin for side-by-side "both equal" votes.
    pub draw_margin: f64,
}

impl Default for SurveyConfig {
    fn default() -> Self {
        SurveyConfig {
            n_respondents_collected: 194,
            exclusion_rate: 19.0 / 194.0,
            side_by_side_per_respondent: 3,
            satisfaction_per_respondent: 6,
            satisfaction_base: 0.76,
            satisfaction_slope: 1.1,
            satisfaction_pivot: 0.78,
            bias_std: 0.05,
            noise_std: 0.09,
            draw_margin: 0.12,
        }
    }
}

/// How a survey respondent perceives quality: UX-dominant. Lay users grade
/// the *reading experience*; expert facets (factual depth, completeness)
/// are what the debate personas weight instead. This split is what lets
/// Fig 3 (tweaked ≥ big for users in the top band) and Fig 5 (the debate
/// still leans Big) coexist — as they do in the paper.
pub fn perceived(q: &ResponseQuality) -> f64 {
    0.2 * q.factual + 0.6 * q.ux + 0.2 * q.relevance
}

pub fn run_survey(items: &[SurveyItem], cfg: &SurveyConfig, seed: u64) -> SurveyResult {
    let mut rng = Rng::substream(seed, "survey");
    let mut result = SurveyResult {
        satisfaction: Band::ALL
            .iter()
            .map(|b| (*b, SatisfactionCell::default(), SatisfactionCell::default()))
            .collect(),
        side_by_side: Band::ALL
            .iter()
            .map(|b| (*b, SideBySideCell::default()))
            .collect(),
        ..Default::default()
    };
    // least-voted-first assignment counters (the paper's even distribution)
    let mut sxs_votes = vec![0u32; items.len()];
    let mut sat_votes = vec![0u32; items.len()];

    let n_valid = (cfg.n_respondents_collected as f64 * (1.0 - cfg.exclusion_rate))
        .round() as usize;
    result.respondents = n_valid;
    result.excluded = cfg.n_respondents_collected - n_valid;

    for _ in 0..n_valid {
        let bias = rng.normal_ms(0.0, cfg.bias_std);
        let draw_margin = (cfg.draw_margin + rng.normal_ms(0.0, 0.03)).max(0.01);

        // --- side-by-side comparisons ---
        for _ in 0..cfg.side_by_side_per_respondent {
            let idx = least_voted(&sxs_votes, &mut rng);
            sxs_votes[idx] += 1;
            let item = &items[idx];
            let pa = perceived(&item.big) + rng.normal_ms(0.0, cfg.noise_std);
            let pb = perceived(&item.tweaked) + rng.normal_ms(0.0, cfg.noise_std);
            let cell = cell_mut(&mut result.side_by_side, item.band);
            if (pa - pb).abs() < draw_margin {
                cell.draw += 1;
            } else if pa > pb {
                cell.big += 1;
            } else {
                cell.small += 1;
            }
        }

        // --- individual satisfaction ratings: 3 big + 3 tweaked ---
        for k in 0..cfg.satisfaction_per_respondent {
            let idx = least_voted(&sat_votes, &mut rng);
            sat_votes[idx] += 1;
            let item = &items[idx];
            let use_big = k % 2 == 0;
            let q = if use_big { perceived(&item.big) } else { perceived(&item.tweaked) };
            let judged = q + bias + rng.normal_ms(0.0, cfg.noise_std);
            let p_sat = (cfg.satisfaction_base
                + cfg.satisfaction_slope * (judged - cfg.satisfaction_pivot))
                .clamp(0.0, 1.0);
            let satisfied = rng.chance(p_sat);
            let row = result
                .satisfaction
                .iter_mut()
                .find(|(b, _, _)| *b == item.band)
                .unwrap();
            let cell = if use_big { &mut row.1 } else { &mut row.2 };
            cell.total += 1;
            if satisfied {
                cell.satisfied += 1;
            }
        }
    }
    result
}

fn least_voted(votes: &[u32], rng: &mut Rng) -> usize {
    let min = *votes.iter().min().unwrap();
    let candidates: Vec<usize> = votes
        .iter()
        .enumerate()
        .filter(|(_, v)| **v == min)
        .map(|(i, _)| i)
        .collect();
    candidates[rng.usize(candidates.len())]
}

fn cell_mut(cells: &mut [(Band, SideBySideCell)], band: Band) -> &mut SideBySideCell {
    &mut cells.iter_mut().find(|(b, _)| *b == band).unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::quality::QualityModel;

    fn items(seed: u64) -> Vec<SurveyItem> {
        // 40 per band, as in the paper
        let mut m = QualityModel::new(seed);
        let mut out = Vec::new();
        for band in Band::ALL {
            for _ in 0..40 {
                out.push(SurveyItem {
                    band,
                    big: m.big_direct(),
                    tweaked: m.small_tweaked(band.midpoint(), None),
                });
            }
        }
        out
    }

    #[test]
    fn respondent_accounting_matches_paper() {
        let r = run_survey(&items(1), &SurveyConfig::default(), 1);
        assert_eq!(r.respondents, 175);
        assert_eq!(r.excluded, 19);
    }

    #[test]
    fn satisfaction_comparable_across_bands() {
        // Fig 3: tweaked ≈ big in all bands; tweaked ≥ big in the top band.
        let r = run_survey(&items(2), &SurveyConfig::default(), 2);
        for (band, big, tweaked) in &r.satisfaction {
            let (b, t) = (big.rate(), tweaked.rate());
            assert!(b > 40.0 && b < 98.0, "{band:?} big={b}");
            assert!((b - t).abs() < 20.0, "{band:?} big={b} tweaked={t}");
        }
        let top = r.satisfaction.iter().find(|(b, _, _)| *b == Band::B90).unwrap();
        assert!(top.2.rate() >= top.1.rate() - 3.0, "top band tweaked should rival big");
    }

    #[test]
    fn side_by_side_draw_plus_small_beats_big_overall() {
        // Fig 4's headline: Draw+Small (274) > Big (213).
        let r = run_survey(&items(3), &SurveyConfig::default(), 3);
        let mut big = 0;
        let mut small_or_draw = 0;
        for (_, c) in &r.side_by_side {
            big += c.big;
            small_or_draw += c.small + c.draw;
        }
        assert!(small_or_draw > big, "draw+small={small_or_draw} big={big}");
    }

    #[test]
    fn vote_totals_match_protocol() {
        let cfg = SurveyConfig::default();
        let r = run_survey(&items(4), &cfg, 4);
        let sxs: u64 = r.side_by_side.iter().map(|(_, c)| c.total()).sum();
        assert_eq!(sxs, 175 * 3);
        let sat: u64 = r
            .satisfaction
            .iter()
            .map(|(_, b, t)| b.total + t.total)
            .sum();
        assert_eq!(sat, 175 * 6);
    }
}
