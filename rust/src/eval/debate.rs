//! Multi-agent LLM-as-evaluator debate (paper §4.2.2 "LLM-as-evaluator
//! pipeline", Table 2, Appendix B; results Figs 5–7).
//!
//! Three personas — Factual Accuracy, User Experience, Relevance &
//! Completeness — each scores both (blinded) responses through its own
//! facet weighting plus observation noise, voting A / B / AB. The debate
//! runs two rounds (ChatEval-style): in round 2 each persona re-scores with
//! its perception partially pulled toward the round-1 panel consensus
//! (peer influence), exactly the role the shared "History" plays in the
//! paper's prompts. The majority verdict wins; ties → AB.

use super::quality::ResponseQuality;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Verdict {
    A,
    B,
    AB,
}

/// A debate persona: facet weights + behavioural constants.
#[derive(Clone, Debug)]
pub struct Persona {
    pub name: &'static str,
    /// Weights over (factual, ux, relevance); sum to 1.
    pub weights: [f64; 3],
    /// Score margin below which the persona calls AB.
    pub tie_margin: f64,
}

pub fn default_personas() -> Vec<Persona> {
    vec![
        Persona {
            name: "Factual Accuracy Evaluator",
            weights: [0.70, 0.10, 0.20],
            tie_margin: 0.045,
        },
        Persona {
            name: "User Experience Evaluator",
            weights: [0.10, 0.70, 0.20],
            tie_margin: 0.055,
        },
        Persona {
            name: "Relevance & Completeness Evaluator",
            weights: [0.15, 0.15, 0.70],
            tie_margin: 0.045,
        },
    ]
}

#[derive(Clone, Copy, Debug)]
pub struct DebateConfig {
    /// Observation noise per persona per round.
    pub noise_std: f64,
    /// Round-2 pull toward the round-1 panel mean (0 = independent).
    pub peer_influence: f64,
    pub rounds: usize,
}

impl Default for DebateConfig {
    fn default() -> Self {
        DebateConfig { noise_std: 0.06, peer_influence: 0.30, rounds: 2 }
    }
}

/// Outcome of one debate.
#[derive(Clone, Debug)]
pub struct DebateOutcome {
    pub verdict: Verdict,
    /// Final-round per-persona verdicts (for the ablation bench).
    pub persona_verdicts: Vec<Verdict>,
}

/// Debate one pair: response A vs response B with latent qualities.
pub fn debate(
    a: &ResponseQuality,
    b: &ResponseQuality,
    personas: &[Persona],
    cfg: &DebateConfig,
    rng: &mut Rng,
) -> DebateOutcome {
    let facets_a = [a.factual, a.ux, a.relevance];
    let facets_b = [b.factual, b.ux, b.relevance];
    // Round 1: independent noisy scoring.
    let mut diffs: Vec<f64> = personas
        .iter()
        .map(|p| {
            let sa: f64 = p.weights.iter().zip(&facets_a).map(|(w, f)| w * f).sum();
            let sb: f64 = p.weights.iter().zip(&facets_b).map(|(w, f)| w * f).sum();
            (sa - sb) + rng.normal_ms(0.0, cfg.noise_std)
        })
        .collect();

    for _round in 1..cfg.rounds {
        // Panel consensus from the previous round.
        let consensus = diffs.iter().sum::<f64>() / diffs.len() as f64;
        diffs = personas
            .iter()
            .zip(&diffs)
            .map(|(p, prev)| {
                let sa: f64 = p.weights.iter().zip(&facets_a).map(|(w, f)| w * f).sum();
                let sb: f64 = p.weights.iter().zip(&facets_b).map(|(w, f)| w * f).sum();
                let fresh = (sa - sb) + rng.normal_ms(0.0, cfg.noise_std * 0.8);
                // The persona "considers other referees' judgements" but is
                // "not required to output the same value": blend.
                let blended = (1.0 - cfg.peer_influence) * fresh
                    + cfg.peer_influence * consensus;
                // Keep a memory of the persona's own prior view too.
                0.8 * blended + 0.2 * prev
            })
            .collect();
    }

    let persona_verdicts: Vec<Verdict> = personas
        .iter()
        .zip(&diffs)
        .map(|(p, d)| {
            if d.abs() < p.tie_margin {
                Verdict::AB
            } else if *d > 0.0 {
                Verdict::A
            } else {
                Verdict::B
            }
        })
        .collect();

    DebateOutcome { verdict: majority(&persona_verdicts), persona_verdicts }
}

/// Majority across persona verdicts; no majority → AB.
pub fn majority(vs: &[Verdict]) -> Verdict {
    let count = |v: Verdict| vs.iter().filter(|x| **x == v).count();
    let (a, b, ab) = (count(Verdict::A), count(Verdict::B), count(Verdict::AB));
    if a > b && a > ab {
        Verdict::A
    } else if b > a && b > ab {
        Verdict::B
    } else if ab > a && ab > b {
        Verdict::AB
    } else {
        Verdict::AB
    }
}

/// Aggregated verdict counts (one figure bar group).
#[derive(Clone, Copy, Debug, Default)]
pub struct VerdictCounts {
    pub a: u64,
    pub b: u64,
    pub ab: u64,
}

impl VerdictCounts {
    pub fn push(&mut self, v: Verdict) {
        match v {
            Verdict::A => self.a += 1,
            Verdict::B => self.b += 1,
            Verdict::AB => self.ab += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.a + self.b + self.ab
    }

    /// Paper metric: share of B (tweaked/small) wins *or* draws — "better
    /// or on par".
    pub fn frac_b_or_draw(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.b + self.ab) as f64 / self.total() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::quality::QualityModel;

    fn q(f: f64) -> ResponseQuality {
        ResponseQuality { factual: f, ux: f, relevance: f }
    }

    #[test]
    fn clear_winner_wins() {
        let personas = default_personas();
        let cfg = DebateConfig::default();
        let mut rng = Rng::new(1);
        let mut a_wins = 0;
        for _ in 0..100 {
            let o = debate(&q(0.9), &q(0.4), &personas, &cfg, &mut rng);
            if o.verdict == Verdict::A {
                a_wins += 1;
            }
        }
        assert!(a_wins >= 95, "a_wins={a_wins}");
    }

    #[test]
    fn equal_quality_mostly_draws_or_splits() {
        let personas = default_personas();
        let cfg = DebateConfig::default();
        let mut rng = Rng::new(2);
        let mut counts = VerdictCounts::default();
        for _ in 0..400 {
            counts.push(debate(&q(0.7), &q(0.7), &personas, &cfg, &mut rng).verdict);
        }
        // symmetric: neither side should dominate
        let a_frac = counts.a as f64 / counts.total() as f64;
        let b_frac = counts.b as f64 / counts.total() as f64;
        assert!((a_frac - b_frac).abs() < 0.12, "a={a_frac} b={b_frac}");
        assert!(counts.ab > 0);
    }

    #[test]
    fn majority_logic() {
        use Verdict::*;
        assert_eq!(majority(&[A, A, B]), A);
        assert_eq!(majority(&[B, AB, B]), B);
        assert_eq!(majority(&[A, B, AB]), AB);
        assert_eq!(majority(&[AB, AB, A]), AB);
    }

    #[test]
    fn peer_influence_increases_consensus() {
        // With high peer influence, persona verdicts agree more often.
        let personas = default_personas();
        let mut rng = Rng::new(3);
        let mut m = QualityModel::new(3);
        let agreement = |peer: f64, rng: &mut Rng, m: &mut QualityModel| {
            let cfg = DebateConfig { peer_influence: peer, ..Default::default() };
            let mut agree = 0;
            for _ in 0..300 {
                let a = m.big_direct();
                let b = m.small_tweaked(0.8, None);
                let o = debate(&a, &b, &personas, &cfg, rng);
                let first = o.persona_verdicts[0];
                if o.persona_verdicts.iter().all(|v| *v == first) {
                    agree += 1;
                }
            }
            agree
        };
        let low = agreement(0.0, &mut rng, &mut m);
        let high = agreement(0.8, &mut rng, &mut m);
        assert!(high > low, "high={high} low={low}");
    }

    #[test]
    fn frac_b_or_draw() {
        let mut c = VerdictCounts::default();
        c.push(Verdict::A);
        c.push(Verdict::B);
        c.push(Verdict::AB);
        c.push(Verdict::AB);
        assert!((c.frac_b_or_draw() - 0.75).abs() < 1e-9);
    }
}
