//! Fault-tolerance substrate: circuit breakers around each backend and the
//! fault-injection harness (a shared [`FaultSwitch`] plus decorators that
//! wrap any [`LanguageModel`] / [`TextEmbedder`] with injectable failures).
//!
//! The breaker is a pure state machine — every transition is driven by an
//! `Instant` the *caller* supplies, so tests step simulated time instead of
//! sleeping. The injection side is deliberately tiny: a mode cell the bench
//! controller thread can flip mid-run (`Error`, `Delay`, `Hang`,
//! `FailAfterTokens`) while the engine thread reads it per call.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::FaultsConfig;
use crate::llm::{BatchDecodeStats, LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use crate::runtime::TextEmbedder;

/// Circuit breaker phases (classic closed → open → half-open cycle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Calls flow; outcomes fill the rolling window.
    Closed,
    /// Calls are rejected without touching the backend.
    Open,
    /// Probe calls are let through; successes close, a failure reopens.
    HalfOpen,
}

impl BreakerState {
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

/// Rolling failure-rate circuit breaker.
///
/// * **Closed**: outcomes land in a bounded window; once at least
///   `min_samples` are present and the failure fraction reaches
///   `failure_ratio`, the breaker opens.
/// * **Open**: `allow` rejects until `open_for` has elapsed, then flips to
///   half-open.
/// * **Half-open**: calls are admitted as probes; `half_open_probes`
///   consecutive successes close the breaker (window reset), any failure
///   reopens it and restarts the cool-down.
pub struct CircuitBreaker {
    /// Rolling outcome window; `true` = failure.
    window: VecDeque<bool>,
    capacity: usize,
    failure_ratio: f32,
    min_samples: usize,
    open_for: Duration,
    half_open_probes: usize,
    state: BreakerState,
    opened_at: Option<Instant>,
    probe_successes: usize,
    /// Lifetime count of closed/half-open → open transitions.
    trips: u64,
}

impl CircuitBreaker {
    pub fn new(
        capacity: usize,
        failure_ratio: f32,
        min_samples: usize,
        open_for: Duration,
        half_open_probes: usize,
    ) -> Self {
        CircuitBreaker {
            window: VecDeque::with_capacity(capacity.max(1)),
            capacity: capacity.max(1),
            failure_ratio,
            min_samples: min_samples.max(1),
            open_for,
            half_open_probes: half_open_probes.max(1),
            state: BreakerState::Closed,
            opened_at: None,
            probe_successes: 0,
            trips: 0,
        }
    }

    pub fn from_config(cfg: &FaultsConfig) -> Self {
        CircuitBreaker::new(
            cfg.breaker_window,
            cfg.breaker_failure_ratio,
            cfg.breaker_min_samples,
            Duration::from_millis(cfg.breaker_open_ms),
            cfg.breaker_half_open_probes,
        )
    }

    /// May a call proceed at `now`? Open breakers flip to half-open (and
    /// admit the call as a probe) once the cool-down has elapsed.
    pub fn allow(&mut self, now: Instant) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                let opened = self.opened_at.expect("open breaker has a timestamp");
                if now.duration_since(opened) >= self.open_for {
                    self.state = BreakerState::HalfOpen;
                    self.probe_successes = 0;
                    true
                } else {
                    false
                }
            }
        }
    }

    pub fn record_success(&mut self, _now: Instant) {
        match self.state {
            BreakerState::HalfOpen => {
                self.probe_successes += 1;
                if self.probe_successes >= self.half_open_probes {
                    self.state = BreakerState::Closed;
                    self.opened_at = None;
                    self.window.clear();
                }
            }
            BreakerState::Closed => self.push(false),
            // A success racing an open breaker (call admitted before the
            // trip) is stale evidence; drop it.
            BreakerState::Open => {}
        }
    }

    pub fn record_failure(&mut self, now: Instant) {
        match self.state {
            BreakerState::HalfOpen => self.trip(now),
            BreakerState::Closed => {
                self.push(true);
                if self.window.len() >= self.min_samples {
                    let failures = self.window.iter().filter(|&&f| f).count();
                    if failures as f32 / self.window.len() as f32 >= self.failure_ratio {
                        self.trip(now);
                    }
                }
            }
            BreakerState::Open => {}
        }
    }

    fn push(&mut self, failure: bool) {
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(failure);
    }

    fn trip(&mut self, now: Instant) {
        self.state = BreakerState::Open;
        self.opened_at = Some(now);
        self.probe_successes = 0;
        self.trips += 1;
        self.window.clear();
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn trips(&self) -> u64 {
        self.trips
    }
}

/// What a wrapped backend does when called.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// Pass through untouched.
    Healthy,
    /// Fail immediately at call/begin time.
    Error,
    /// Succeed after an injected latency.
    Delay(Duration),
    /// Sessions never make progress (each `advance` sleeps ~1ms and reports
    /// more work forever) — only a deadline or generation timeout ends them.
    /// Blocking calls and embedder calls refuse instead of wedging the
    /// engine thread.
    Hang,
    /// Sessions error after N successful `advance` calls (mid-decode
    /// failure); for embedders, fail every call after N successful batches.
    FailAfterTokens(usize),
}

/// Shared, thread-safe fault mode cell: the bench/test controller flips it
/// mid-run while the engine thread reads it on every wrapped call.
#[derive(Clone)]
pub struct FaultSwitch(Arc<Mutex<FaultMode>>);

impl FaultSwitch {
    pub fn new(mode: FaultMode) -> Self {
        FaultSwitch(Arc::new(Mutex::new(mode)))
    }

    pub fn healthy() -> Self {
        FaultSwitch::new(FaultMode::Healthy)
    }

    pub fn set(&self, mode: FaultMode) {
        *self.0.lock().unwrap() = mode;
    }

    pub fn get(&self) -> FaultMode {
        *self.0.lock().unwrap()
    }
}

impl Default for FaultSwitch {
    fn default() -> Self {
        FaultSwitch::healthy()
    }
}

/// [`LanguageModel`] decorator that injects the switch's current fault on
/// every call. The mode is sampled at `begin` time, so an outage flipped
/// mid-run hits new sessions while in-flight ones finish normally (matching
/// how a real backend outage presents to a connection pool).
pub struct FaultyLlm {
    inner: Box<dyn LanguageModel>,
    switch: FaultSwitch,
}

impl FaultyLlm {
    pub fn new(inner: Box<dyn LanguageModel>, switch: FaultSwitch) -> Self {
        FaultyLlm { inner, switch }
    }

    fn begin_inner(
        &mut self,
        start: impl FnOnce(&mut Box<dyn LanguageModel>) -> Result<Box<dyn LlmSession>>,
    ) -> Result<Box<dyn LlmSession>> {
        match self.switch.get() {
            FaultMode::Healthy => start(&mut self.inner),
            FaultMode::Error => {
                bail!("injected fault: {} unavailable", self.inner.name())
            }
            FaultMode::Delay(d) => {
                std::thread::sleep(d);
                start(&mut self.inner)
            }
            FaultMode::Hang => Ok(Box::new(HungSession)),
            FaultMode::FailAfterTokens(n) => Ok(Box::new(FailingSession {
                inner: start(&mut self.inner)?,
                remaining: n,
                name: self.inner.name().to_string(),
            })),
        }
    }
}

impl LanguageModel for FaultyLlm {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        if self.switch.get() == FaultMode::Hang {
            // A blocking call cannot be timed out from outside; refuse
            // rather than wedge the caller forever.
            bail!("injected fault: {} hung (blocking call refused)", self.inner.name());
        }
        let mut session = self.begin_respond(query)?;
        while session.advance()? {}
        session.finish()
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        if self.switch.get() == FaultMode::Hang {
            bail!("injected fault: {} hung (blocking call refused)", self.inner.name());
        }
        let mut session = self.begin_tweak(prompt)?;
        while session.advance()? {}
        session.finish()
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        let query = query.to_string();
        self.begin_inner(move |inner| inner.begin_respond(&query))
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        let prompt = prompt.clone();
        self.begin_inner(move |inner| inner.begin_tweak(&prompt))
    }

    fn batch_stats(&self) -> Option<BatchDecodeStats> {
        self.inner.batch_stats()
    }
}

/// A session that never finishes: `advance` paces itself (~1ms) so a
/// deadline/timeout check elsewhere can reap it without a busy spin.
struct HungSession;

impl LlmSession for HungSession {
    fn advance(&mut self) -> Result<bool> {
        std::thread::sleep(Duration::from_millis(1));
        Ok(true)
    }

    fn is_done(&self) -> bool {
        false
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        bail!("injected fault: hung session never finished")
    }
}

/// A session that errors after `remaining` successful advances — the
/// mid-decode failure shape (backend dies partway through a generation).
struct FailingSession {
    inner: Box<dyn LlmSession>,
    remaining: usize,
    name: String,
}

impl LlmSession for FailingSession {
    fn advance(&mut self) -> Result<bool> {
        if self.remaining == 0 {
            bail!("injected fault: {} failed mid-generation", self.name);
        }
        self.remaining -= 1;
        self.inner.advance()
    }

    fn is_done(&self) -> bool {
        self.inner.is_done()
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        self.inner.finish()
    }
}

/// [`TextEmbedder`] decorator mirroring [`FaultyLlm`]. `Hang` surfaces as a
/// paced error (an embed call is synchronous on the engine thread — a true
/// wedge would stall every request, not just this one).
pub struct FaultyEmbedder {
    inner: Box<dyn TextEmbedder>,
    switch: FaultSwitch,
    successes: Cell<usize>,
}

impl FaultyEmbedder {
    pub fn new(inner: Box<dyn TextEmbedder>, switch: FaultSwitch) -> Self {
        FaultyEmbedder { inner, switch, successes: Cell::new(0) }
    }
}

impl TextEmbedder for FaultyEmbedder {
    fn out_dim(&self) -> usize {
        self.inner.out_dim()
    }

    fn embed_batch(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        match self.switch.get() {
            FaultMode::Healthy => {
                let out = self.inner.embed_batch(texts)?;
                self.successes.set(self.successes.get() + 1);
                Ok(out)
            }
            FaultMode::Error => bail!("injected fault: embedder unavailable"),
            FaultMode::Delay(d) => {
                std::thread::sleep(d);
                let out = self.inner.embed_batch(texts)?;
                self.successes.set(self.successes.get() + 1);
                Ok(out)
            }
            FaultMode::Hang => {
                std::thread::sleep(Duration::from_millis(1));
                bail!("injected fault: embedder hung")
            }
            FaultMode::FailAfterTokens(n) => {
                if self.successes.get() >= n {
                    bail!("injected fault: embedder failed after {n} batches");
                }
                let out = self.inner.embed_batch(texts)?;
                self.successes.set(self.successes.get() + 1);
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::MockLlm;
    use crate::runtime::NativeBowEmbedder;

    fn breaker() -> CircuitBreaker {
        // window 8, trip at ≥50% failures over ≥4 samples, 100ms cool-down,
        // 2 probes to close.
        CircuitBreaker::new(8, 0.5, 4, Duration::from_millis(100), 2)
    }

    #[test]
    fn breaker_opens_on_failure_ratio() {
        let mut b = breaker();
        let t0 = Instant::now();
        assert_eq!(b.state(), BreakerState::Closed);
        // 3 failures: below min_samples, still closed.
        for _ in 0..3 {
            b.record_failure(t0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure(t0);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
        assert!(!b.allow(t0));
        assert!(!b.allow(t0 + Duration::from_millis(50)));
    }

    #[test]
    fn breaker_ignores_sparse_failures() {
        let mut b = breaker();
        let t0 = Instant::now();
        // Alternate: 50% would trip, so use 1 failure per 3 successes.
        for _ in 0..6 {
            b.record_success(t0);
            b.record_success(t0);
            b.record_success(t0);
            b.record_failure(t0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_half_open_closes_after_probes() {
        let mut b = breaker();
        let t0 = Instant::now();
        for _ in 0..4 {
            b.record_failure(t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        let t1 = t0 + Duration::from_millis(120);
        assert!(b.allow(t1), "cool-down elapsed: probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_success(t1);
        assert_eq!(b.state(), BreakerState::HalfOpen, "1 of 2 probes");
        b.record_success(t1);
        assert_eq!(b.state(), BreakerState::Closed);
        // Window was reset: old failures don't haunt the fresh state.
        b.record_failure(t1);
        b.record_failure(t1);
        b.record_failure(t1);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_half_open_failure_reopens() {
        let mut b = breaker();
        let t0 = Instant::now();
        for _ in 0..4 {
            b.record_failure(t0);
        }
        let t1 = t0 + Duration::from_millis(120);
        assert!(b.allow(t1));
        b.record_failure(t1);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 2);
        // Cool-down restarts from the reopen.
        assert!(!b.allow(t1 + Duration::from_millis(50)));
        assert!(b.allow(t1 + Duration::from_millis(120)));
    }

    #[test]
    fn faulty_llm_error_mode_fails_begin() {
        let switch = FaultSwitch::new(FaultMode::Error);
        let mut m = FaultyLlm::new(Box::new(MockLlm::new("small")), switch.clone());
        assert!(m.begin_respond("q").is_err());
        assert!(m.respond("q").is_err());
        switch.set(FaultMode::Healthy);
        assert!(m.respond("q").unwrap().text.contains("small-fresh"));
    }

    #[test]
    fn faulty_llm_hang_session_never_finishes() {
        let mut m =
            FaultyLlm::new(Box::new(MockLlm::new("small")), FaultSwitch::new(FaultMode::Hang));
        let mut s = m.begin_respond("q").unwrap();
        assert!(s.advance().unwrap());
        assert!(s.advance().unwrap());
        assert!(!s.is_done());
        assert!(s.finish().is_err());
        // Blocking calls refuse instead of wedging.
        assert!(m.respond("q").is_err());
    }

    #[test]
    fn faulty_llm_fails_after_n_tokens() {
        let inner = MockLlm::new("big").with_pace(8, Duration::ZERO);
        let mut m =
            FaultyLlm::new(Box::new(inner), FaultSwitch::new(FaultMode::FailAfterTokens(3)));
        let mut s = m.begin_respond("q").unwrap();
        let mut advances = 0;
        let err = loop {
            match s.advance() {
                Ok(true) => advances += 1,
                Ok(false) => panic!("session completed despite injection"),
                Err(e) => break e,
            }
        };
        assert_eq!(advances, 3);
        assert!(err.to_string().contains("injected fault"));
    }

    #[test]
    fn faulty_embedder_modes() {
        let switch = FaultSwitch::healthy();
        let e = FaultyEmbedder::new(Box::new(NativeBowEmbedder::new(16, 7)), switch.clone());
        assert_eq!(e.out_dim(), 16);
        assert_eq!(e.embed_batch(&["a"]).unwrap().len(), 1);
        switch.set(FaultMode::Error);
        assert!(e.embed_batch(&["a"]).is_err());
        switch.set(FaultMode::FailAfterTokens(2));
        // One success already recorded; one more allowed, then failure.
        assert!(e.embed_batch(&["b"]).is_ok());
        assert!(e.embed_batch(&["c"]).is_err());
        switch.set(FaultMode::Healthy);
        assert!(e.embed_batch(&["d"]).is_ok());
    }
}
