//! Mini bench harness (criterion is unavailable offline).
//!
//! Provides timed measurement with warmup + repetitions and a stable text
//! report. Each `benches/*.rs` binary (registered with `harness = false`)
//! uses this to print the rows of one paper table/figure; `cargo bench`
//! runs them all.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Embedder, Runtime};
use crate::util::Summary;

/// Standard bench bootstrap: load the artifact runtime + compiled embedder.
/// Honors `TWEAKLLM_ARTIFACTS` (defaults to `artifacts/`).
pub fn load_runtime() -> Result<Runtime> {
    let dir = std::env::var("TWEAKLLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Runtime::load(&dir, &[])
}

/// Load runtime + embedder together (most figure benches only embed).
pub fn load_embedder() -> Result<(Runtime, Embedder)> {
    let rt = load_runtime()?;
    let e = Embedder::new(&rt)?;
    Ok((rt, e))
}

/// Bench arg helper: `cargo bench --bench x -- --n 500` style flags, also
/// tolerating the harness's own flags (e.g. `--bench`).
pub fn bench_args() -> crate::util::Args {
    crate::util::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
}

/// Measure a closure: `warmup` untimed runs, then `iters` timed runs.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6); // micros
    }
    Summary::of(&samples)
}

/// Format a measurement row.
pub fn row(name: &str, s: &Summary) -> String {
    format!(
        "{name:<40} n={:<5} mean={:>10.1}us p50={:>10.1}us p99={:>10.1}us",
        s.n, s.mean, s.p50, s.p99
    )
}

/// Section header for bench output.
pub fn section(title: &str) -> String {
    format!("\n=== {title} ===")
}

/// A simple fixed-width table builder for figure reproduction output.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n## {}\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(
            &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_and_summarizes() {
        let mut n = 0u64;
        let s = measure(2, 10, || {
            n += 1;
        });
        assert_eq!(n, 12);
        assert_eq!(s.n, 10);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig X", &["threshold", "precision", "recall"]);
        t.push(vec!["0.70".into(), "0.90".into(), "0.85".into()]);
        t.push(vec!["0.97".into(), "0.97".into(), "0.20".into()]);
        let r = t.render();
        assert!(r.contains("Fig X"));
        assert!(r.contains("0.97"));
        assert_eq!(r.lines().count(), 6);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push(vec!["1".into()]);
    }
}
