//! Synthetic conversational traces (the LMSYS-Chat-1M / WildChat-1M
//! stand-ins, §4.1).
//!
//! A trace is a stream of first-turn queries drawn from a Zipf-popular
//! intent pool: popular intents recur as exact repeats or paraphrases
//! (cache-hit mass), the long tail is freeform one-offs (cache-miss mass).
//! Per-corpus profiles set those proportions so the hit-rate-vs-threshold
//! curves land in the paper's regimes: LMSYS ~68% of queries ≥0.8 cosine
//! after half-insert, WildChat ~40% (Figs 8–9).

use super::{realize, IntentKey, QueryRecord};
use crate::datasets::vocabulary::{DOMAINS, FREEFORM};
use crate::util::{Rng, ZipfSampler};

#[derive(Clone, Copy, Debug)]
pub struct TraceProfile {
    pub name: &'static str,
    /// Size of the recurring-intent pool.
    pub n_intents: usize,
    /// Zipf exponent over the pool (higher = heavier head = more repeats).
    pub zipf_exponent: f64,
    /// Probability a query is a freeform long-tail one-off.
    pub frac_freeform: f64,
    /// Probability a recurring query repeats a previous *exact* wording.
    pub frac_exact_repeat: f64,
}

impl TraceProfile {
    /// LMSYS-like: crowd of users poking at the same popular prompts;
    /// heavy head, many exact repeats ("numerous identical queries", §6.1).
    pub fn lmsys() -> TraceProfile {
        TraceProfile {
            name: "lmsys_like",
            n_intents: 5000,
            zipf_exponent: 1.02,
            frac_freeform: 0.27,
            frac_exact_repeat: 0.30,
        }
    }

    /// WildChat-like: more diverse, longer tail, fewer repeats.
    pub fn wildchat() -> TraceProfile {
        TraceProfile {
            name: "wildchat_like",
            n_intents: 22000,
            zipf_exponent: 0.75,
            frac_freeform: 0.55,
            frac_exact_repeat: 0.08,
        }
    }
}

/// A generated trace: ordered queries (first user turns).
pub struct ChatTrace {
    pub profile: TraceProfile,
    pub queries: Vec<QueryRecord>,
}

impl ChatTrace {
    pub fn generate(profile: TraceProfile, n_queries: usize, seed: u64) -> ChatTrace {
        let mut rng = Rng::substream(seed, profile.name);
        // Build the recurring intent pool.
        let mut pool: Vec<IntentKey> = Vec::with_capacity(profile.n_intents);
        for v in 0..profile.n_intents {
            pool.push(random_trace_intent(&mut rng, v));
        }
        let zipf = ZipfSampler::new(pool.len(), profile.zipf_exponent);
        // Canonical wording per intent (for exact repeats).
        let canonical: Vec<String> =
            pool.iter().map(|i| realize(i, &mut rng)).collect();

        let mut queries = Vec::with_capacity(n_queries);
        let mut freeform_counter: u32 = 0;
        for _ in 0..n_queries {
            if rng.chance(profile.frac_freeform) {
                // long-tail one-off: unique freeform intent
                freeform_counter += 1;
                let intent = IntentKey {
                    domain: rng.usize(DOMAINS.len()) as u16,
                    entity: rng.usize(8) as u16,
                    attribute: rng.usize(6) as u16,
                    polarity: 2,
                    class: 255,
                    variant: (freeform_counter % FREEFORM.len() as u32) as u8,
                };
                let mut text = realize(&intent, &mut rng);
                // salt with a unique token so one-offs never collide exactly
                text = format!(
                    "{text} {} {} {}",
                    unique_tag(freeform_counter),
                    unique_tag(freeform_counter.wrapping_mul(2654435761)),
                    unique_tag(freeform_counter.wrapping_mul(40503).wrapping_add(7))
                );
                queries.push(QueryRecord { text, intent });
            } else {
                let idx = zipf.sample(&mut rng);
                let intent = pool[idx];
                let text = if rng.chance(profile.frac_exact_repeat) {
                    canonical[idx].clone()
                } else {
                    realize(&intent, &mut rng)
                };
                queries.push(QueryRecord { text, intent });
            }
        }
        ChatTrace { profile, queries }
    }

    /// Split into (inserted half, queried half) per §4.2.3.
    pub fn halves(&self) -> (&[QueryRecord], &[QueryRecord]) {
        let mid = self.queries.len() / 2;
        (&self.queries[..mid], &self.queries[mid..])
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }
}

fn random_trace_intent(rng: &mut Rng, variant: usize) -> IntentKey {
    let domain = rng.usize(DOMAINS.len()) as u16;
    let d = &DOMAINS[domain as usize];
    let class = rng.usize(5) as u8;
    IntentKey {
        domain,
        entity: rng.usize(d.entities.len()) as u16,
        attribute: rng.usize(d.attributes.len()) as u16,
        polarity: if class == 0 { rng.usize(2) as u8 } else { 2 },
        class,
        variant: (variant % 251) as u8,
    }
}

fn unique_tag(counter: u32) -> String {
    // Deterministic unique word outside the synonym/filler vocabulary.
    format!("ref{counter}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn generates_requested_size() {
        let t = ChatTrace::generate(TraceProfile::lmsys(), 1000, 1);
        assert_eq!(t.len(), 1000);
    }

    #[test]
    fn lmsys_has_more_exact_repeats_than_wildchat() {
        let count_exact = |t: &ChatTrace| {
            let mut seen: HashMap<&str, usize> = HashMap::new();
            let mut repeats = 0;
            for q in &t.queries {
                let c = seen.entry(q.text.as_str()).or_insert(0);
                if *c > 0 {
                    repeats += 1;
                }
                *c += 1;
            }
            repeats
        };
        let l = ChatTrace::generate(TraceProfile::lmsys(), 4000, 2);
        let w = ChatTrace::generate(TraceProfile::wildchat(), 4000, 2);
        assert!(
            count_exact(&l) > count_exact(&w) * 2,
            "lmsys={} wildchat={}",
            count_exact(&l),
            count_exact(&w)
        );
    }

    #[test]
    fn freeform_oneoffs_are_unique_text() {
        let t = ChatTrace::generate(TraceProfile::wildchat(), 2000, 3);
        let freeform: Vec<&str> = t
            .queries
            .iter()
            .filter(|q| q.intent.class == 255)
            .map(|q| q.text.as_str())
            .collect();
        let mut dedup = freeform.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(freeform.len(), dedup.len());
        assert!(freeform.len() > 400);
    }

    #[test]
    fn popular_intents_recur_across_halves() {
        let t = ChatTrace::generate(TraceProfile::lmsys(), 12_000, 4);
        let (first, second) = t.halves();
        let first_intents: std::collections::HashSet<_> =
            first.iter().map(|q| q.intent).collect();
        let recur = second
            .iter()
            .filter(|q| first_intents.contains(&q.intent))
            .count();
        // a solid share of second-half queries must have intent mass in the
        // first half — that's the cache-hit opportunity (Fig 8 regime)
        assert!(
            recur as f64 > second.len() as f64 * 0.4,
            "recur={recur}/{}",
            second.len()
        );
    }

    #[test]
    fn deterministic() {
        let a = ChatTrace::generate(TraceProfile::lmsys(), 100, 9);
        let b = ChatTrace::generate(TraceProfile::lmsys(), 100, 9);
        for (x, y) in a.queries.iter().zip(&b.queries) {
            assert_eq!(x.text, y.text);
        }
    }
}
