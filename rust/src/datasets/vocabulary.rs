//! Generation grammar for the synthetic datasets: topic/entity/attribute
//! grids, question templates, synonym groups, filler phrases, polarity
//! pairs.
//!
//! The grids are the ground truth of the benchmark: two queries realized
//! from the same (topic, entity, attribute, polarity) intent are duplicates
//! *by construction* (the stand-in for Quora's human annotations), while
//! hard negatives flip exactly one facet — reproducing the "similar words,
//! opposite meaning" failure mode the paper's C1 is about.

/// One topical domain: a name, its entities, and its attributes.
pub struct Domain {
    pub name: &'static str,
    pub entities: &'static [&'static str],
    pub attributes: &'static [&'static str],
}

pub const DOMAINS: &[Domain] = &[
    Domain {
        name: "programming",
        entities: &["python", "rust", "java", "javascript", "golang", "c++", "haskell", "kotlin", "swift", "ruby"],
        attributes: &["performance", "safety", "readability", "popularity", "tooling", "concurrency", "portability", "ecosystem"],
    },
    Domain {
        name: "nutrition",
        entities: &["coffee", "green tea", "red meat", "chocolate", "eggs", "milk", "salt", "sugar", "olive oil", "honey"],
        attributes: &["health", "energy", "digestion", "heart health", "weight loss", "sleep", "skin", "immunity"],
    },
    Domain {
        name: "finance",
        entities: &["bitcoin", "gold", "index funds", "real estate", "bonds", "savings accounts", "stocks", "options", "commodities", "etfs"],
        attributes: &["returns", "risk", "liquidity", "taxes", "inflation protection", "volatility", "fees", "diversification"],
    },
    Domain {
        name: "fitness",
        entities: &["running", "swimming", "yoga", "weightlifting", "cycling", "pilates", "boxing", "hiking", "rowing", "crossfit"],
        attributes: &["endurance", "strength", "flexibility", "recovery", "fat loss", "posture", "joint health", "mental health"],
    },
    Domain {
        name: "travel",
        entities: &["japan", "italy", "iceland", "thailand", "morocco", "peru", "portugal", "vietnam", "turkey", "greece"],
        attributes: &["food", "cost", "safety", "weather", "culture", "transport", "nightlife", "nature"],
    },
    Domain {
        name: "technology",
        entities: &["smartphones", "laptops", "electric cars", "smart watches", "drones", "tablets", "vr headsets", "routers", "cameras", "printers"],
        attributes: &["battery life", "price", "durability", "performance", "privacy", "repairability", "design", "software support"],
    },
    Domain {
        name: "science",
        entities: &["black holes", "vaccines", "photosynthesis", "dna", "antibiotics", "earthquakes", "neurons", "glaciers", "enzymes", "magnets"],
        attributes: &["mechanism", "discovery", "measurement", "applications", "limits", "history", "risks", "evolution"],
    },
    Domain {
        name: "cooking",
        entities: &["sourdough", "risotto", "ramen", "steak", "curry", "pizza dough", "pancakes", "dumplings", "tacos", "pasta"],
        attributes: &["texture", "flavor", "timing", "temperature", "ingredients", "technique", "storage", "seasoning"],
    },
    Domain {
        name: "pets",
        entities: &["golden retrievers", "siamese cats", "parrots", "hamsters", "goldfish", "rabbits", "turtles", "ferrets", "geckos", "huskies"],
        attributes: &["diet", "training", "grooming", "lifespan", "temperament", "exercise", "health issues", "cost"],
    },
    Domain {
        name: "career",
        entities: &["data science", "nursing", "teaching", "law", "accounting", "marketing", "plumbing", "architecture", "journalism", "consulting"],
        attributes: &["salary", "job security", "work life balance", "education requirements", "growth", "stress", "remote options", "demand"],
    },
    Domain {
        name: "history",
        entities: &["the roman empire", "the silk road", "the renaissance", "the industrial revolution", "ancient egypt", "the cold war", "the vikings", "the ottoman empire", "the maya", "feudal japan"],
        attributes: &["economy", "decline", "inventions", "daily life", "warfare", "trade", "religion", "legacy"],
    },
    Domain {
        name: "gardening",
        entities: &["tomatoes", "roses", "succulents", "basil", "orchids", "lavender", "ferns", "peppers", "strawberries", "bonsai"],
        attributes: &["watering", "sunlight", "soil", "pruning", "pests", "fertilizer", "propagation", "winter care"],
    },
];

/// Question templates. `{e}` = entity, `{a}` = attribute, `{p}` = polarity
/// adjective, `{d}` = domain name. Templates in the same *class* ask the
/// same thing (swapping them preserves intent).
pub struct Template {
    pub text: &'static str,
    /// Intent class: templates sharing a class are mutual paraphrases.
    pub class: u8,
}

pub const TEMPLATES: &[Template] = &[
    // class 0: polarity-judgement question — the paper's canonical example
    Template { text: "why is {e} {p} for {a}?", class: 0 },
    Template { text: "what makes {e} {p} when it comes to {a}?", class: 0 },
    Template { text: "how come {e} is {p} for {a}?", class: 0 },
    Template { text: "can you explain why {e} is {p} for {a}?", class: 0 },
    // class 1: factual explanation
    Template { text: "how does {a} work for {e}?", class: 1 },
    Template { text: "explain the {a} of {e}", class: 1 },
    Template { text: "what should i know about the {a} of {e}?", class: 1 },
    Template { text: "tell me about {a} and {e}", class: 1 },
    // class 2: recommendation
    Template { text: "what is the best way to improve {a} with {e}?", class: 2 },
    Template { text: "how can i get better {a} using {e}?", class: 2 },
    Template { text: "any tips on {a} for {e}?", class: 2 },
    // class 3: comparison-lite (entity vs domain norm)
    Template { text: "is {e} better than most {d} options for {a}?", class: 3 },
    Template { text: "compared to other {d} choices, how is {e} for {a}?", class: 3 },
    // class 4: beginner question
    Template { text: "i am new to {d}, is {e} a good place to start for {a}?", class: 4 },
    Template { text: "as a beginner in {d}, should i pick {e} for {a}?", class: 4 },
];

/// Polarity adjective pairs: index 0 = positive, 1 = negative. Flipping
/// polarity swaps one word while keeping every other token — the hard
/// negative GPTCache mis-serves.
pub const POLARITY: &[[&str; 2]] = &[
    ["good", "bad"],
    ["great", "terrible"],
    ["helpful", "harmful"],
    ["recommended", "discouraged"],
    ["effective", "ineffective"],
];

/// Filler phrases optionally prepended/appended during paraphrasing.
pub const PREFIX_FILLERS: &[&str] = &[
    "please",
    "quick question",
    "hey",
    "i was wondering",
    "honest question",
    "serious question",
];

pub const SUFFIX_FILLERS: &[&str] = &[
    "thanks",
    "thanks in advance",
    "appreciate any help",
    "just curious",
];

/// Synonym groups applied word-by-word during paraphrasing.
pub const SYNONYMS: &[&[&str]] = &[
    &["why", "how come"],
    &["explain", "describe", "clarify"],
    &["best", "ideal", "top"],
    &["improve", "boost", "increase"],
    &["tips", "advice", "suggestions"],
    &["good", "solid", "decent"],
    &["better", "superior"],
    &["know", "understand", "learn"],
];

/// Free-form conversational openers for the chat traces (queries that are
/// NOT grid questions — the long tail real corpora have).
pub const FREEFORM: &[&str] = &[
    "write a short poem about {e}",
    "summarize the main ideas behind {a} in {d}",
    "draft an email asking my landlord about {e}",
    "give me a study plan for learning about {e}",
    "brainstorm names for a blog about {d}",
    "translate this sentence about {e} into french",
    "write a product description for {e}",
    "roleplay as an expert in {d} and critique {e}",
    "make a checklist for {a} when dealing with {e}",
    "pretend you are my coach and motivate me about {a}",
    "list five facts about {e}",
    "write a tweet about {a} in {d}",
];

pub fn domain_count() -> usize {
    DOMAINS.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_nonempty_and_rich() {
        assert!(DOMAINS.len() >= 10);
        for d in DOMAINS {
            assert!(d.entities.len() >= 8, "{}", d.name);
            assert!(d.attributes.len() >= 6, "{}", d.name);
        }
        assert!(TEMPLATES.len() >= 12);
        assert!(FREEFORM.len() >= 10);
    }

    #[test]
    fn template_classes_have_paraphrases() {
        for class in 0..5u8 {
            let n = TEMPLATES.iter().filter(|t| t.class == class).count();
            assert!(n >= 2, "class {class} has {n} templates");
        }
    }

    #[test]
    fn polarity_pairs_differ() {
        for p in POLARITY {
            assert_ne!(p[0], p[1]);
        }
    }
}
