//! Synthetic Question Pairs dataset (the Quora stand-in, §4.1).
//!
//! Each pair carries a construction-time duplicate label:
//! * **duplicates** — two independent realizations of the SAME intent
//!   (template swap within class + synonym/filler paraphrasing);
//! * **hard negatives** — realizations of two intents differing in exactly
//!   one facet (polarity flip / entity swap / attribute swap): high token
//!   overlap, different intent — the precision killers of Fig 2;
//! * **easy negatives** — unrelated intents.

use super::{realize, IntentKey, QueryRecord};
use crate::datasets::vocabulary::DOMAINS;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct LabeledPair {
    pub q1: QueryRecord,
    pub q2: QueryRecord,
    pub is_duplicate: bool,
}

#[derive(Clone, Copy, Debug)]
pub struct PairProfile {
    pub frac_duplicates: f64,
    /// Among negatives, fraction that are hard (single-facet) negatives.
    pub frac_hard_negatives: f64,
}

impl Default for PairProfile {
    fn default() -> Self {
        // Quora-like: curated to be duplicate-heavy with adversarial
        // lexical overlap in the negatives.
        PairProfile { frac_duplicates: 0.5, frac_hard_negatives: 0.75 }
    }
}

pub struct QuestionPairDataset {
    pub pairs: Vec<LabeledPair>,
}

impl QuestionPairDataset {
    pub fn generate(n_pairs: usize, seed: u64) -> Self {
        Self::generate_with(n_pairs, seed, PairProfile::default())
    }

    pub fn generate_with(n_pairs: usize, seed: u64, profile: PairProfile) -> Self {
        let mut rng = Rng::substream(seed, "question_pairs");
        let mut pairs = Vec::with_capacity(n_pairs);
        for _ in 0..n_pairs {
            let base = random_intent(&mut rng);
            let dup = rng.chance(profile.frac_duplicates);
            let other = if dup {
                base
            } else if rng.chance(profile.frac_hard_negatives) {
                mutate_one_facet(&base, &mut rng)
            } else {
                random_intent(&mut rng)
            };
            let q1 = QueryRecord { text: realize(&base, &mut rng), intent: base };
            let q2 = QueryRecord { text: realize(&other, &mut rng), intent: other };
            pairs.push(LabeledPair { q1, q2, is_duplicate: dup });
        }
        QuestionPairDataset { pairs }
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

pub fn random_intent(rng: &mut Rng) -> IntentKey {
    let domain = rng.usize(DOMAINS.len()) as u16;
    let d = &DOMAINS[domain as usize];
    let class = rng.usize(5) as u8;
    IntentKey {
        domain,
        entity: rng.usize(d.entities.len()) as u16,
        attribute: rng.usize(d.attributes.len()) as u16,
        // class 0 templates are polar; the rest neutral
        polarity: if class == 0 { rng.usize(2) as u8 } else { 2 },
        class,
        variant: 0,
    }
}

/// Flip exactly one facet → a hard negative sharing most surface tokens.
pub fn mutate_one_facet(base: &IntentKey, rng: &mut Rng) -> IntentKey {
    let d = &DOMAINS[base.domain as usize];
    let mut m = *base;
    // Prefer the polarity flip when available (the paper's canonical case).
    let choice = if base.polarity != 2 { rng.usize(3) } else { 1 + rng.usize(2) };
    match choice {
        0 => m.polarity = 1 - base.polarity,
        1 => {
            m.entity = ((base.entity as usize + 1 + rng.usize(d.entities.len() - 1))
                % d.entities.len()) as u16
        }
        _ => {
            m.attribute = ((base.attribute as usize
                + 1
                + rng.usize(d.attributes.len() - 1))
                % d.attributes.len()) as u16
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::intent_affinity;

    #[test]
    fn generates_requested_count() {
        let ds = QuestionPairDataset::generate(100, 7);
        assert_eq!(ds.len(), 100);
    }

    #[test]
    fn labels_match_intents() {
        let ds = QuestionPairDataset::generate(500, 1);
        for p in &ds.pairs {
            assert_eq!(p.is_duplicate, p.q1.intent == p.q2.intent);
        }
    }

    #[test]
    fn duplicate_fraction_close_to_profile() {
        let ds = QuestionPairDataset::generate(2000, 2);
        let dups = ds.pairs.iter().filter(|p| p.is_duplicate).count();
        let frac = dups as f64 / ds.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn hard_negatives_have_moderate_affinity() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let base = random_intent(&mut rng);
            let hard = mutate_one_facet(&base, &mut rng);
            assert_ne!(base, hard);
            let aff = intent_affinity(&base, &hard);
            assert!(aff < 1.0 && aff > 0.05, "aff={aff}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = QuestionPairDataset::generate(50, 42);
        let b = QuestionPairDataset::generate(50, 42);
        for (x, y) in a.pairs.iter().zip(&b.pairs) {
            assert_eq!(x.q1.text, y.q1.text);
            assert_eq!(x.is_duplicate, y.is_duplicate);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = QuestionPairDataset::generate(50, 1);
        let b = QuestionPairDataset::generate(50, 2);
        assert!(a.pairs.iter().zip(&b.pairs).any(|(x, y)| x.q1.text != y.q1.text));
    }
}
