//! Synthetic dataset substrates.
//!
//! The paper evaluates on Quora Question Pairs, LMSYS-Chat-1M and
//! WildChat-1M — all gated behind downloads we don't have offline. These
//! generators produce the closest synthetic equivalents (DESIGN.md
//! "Substitutions"): intent-grid question pairs with construction-time
//! duplicate labels, and Zipf-popularity chat traces with per-corpus
//! duplicate profiles.

pub mod chat_traces;
pub mod question_pairs;
pub mod vocabulary;

pub use chat_traces::{ChatTrace, TraceProfile};
pub use question_pairs::{LabeledPair, QuestionPairDataset};

use crate::util::Rng;
use vocabulary::{DOMAINS, POLARITY, PREFIX_FILLERS, SUFFIX_FILLERS, SYNONYMS, TEMPLATES};

/// Ground-truth intent of a generated query. Two queries are *duplicates*
/// iff their intents are equal (facet-for-facet).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct IntentKey {
    pub domain: u16,
    pub entity: u16,
    pub attribute: u16,
    /// 0 = positive, 1 = negative, 2 = neutral (non-polar templates).
    pub polarity: u8,
    /// Template class (see vocabulary::Template::class); 255 = freeform.
    pub class: u8,
    /// Distinguishes freeform intents sharing a grid cell.
    pub variant: u8,
}

/// A generated query with its ground truth.
#[derive(Clone, Debug)]
pub struct QueryRecord {
    pub text: String,
    pub intent: IntentKey,
}

/// Semantic affinity of two intents in [0, 1]: how appropriate a response
/// for `b` is as a basis for answering `a`. This is the ground truth the
/// quality model (eval::quality) consumes. The asymmetric cases don't
/// matter at our granularity, so it's symmetric.
pub fn intent_affinity(a: &IntentKey, b: &IntentKey) -> f64 {
    if a == b {
        return 1.0;
    }
    if a.domain != b.domain {
        return 0.15; // unrelated worlds (still generic-answer salvageable)
    }
    // Same domain: start from a base and dock per differing facet.
    let mut aff: f64 = 0.9;
    if a.entity != b.entity {
        // a cached answer about a sibling entity is still a usable basis
        // (same structure, same domain knowledge) — the tweak rewrites the
        // subject
        aff -= 0.22;
    }
    if a.attribute != b.attribute {
        aff -= 0.18;
    }
    if a.class != b.class {
        aff -= 0.10;
    }
    if a.polarity != b.polarity && a.polarity != 2 && b.polarity != 2 {
        // Polarity flip: surface-similar, intent-opposite — the paper's
        // canonical false-positive ("Why is X good?" vs "Why is X bad?").
        aff -= 0.45;
    }
    if a.variant != b.variant {
        aff -= 0.10;
    }
    aff.clamp(0.02, 1.0)
}

/// Realize an intent as text. `style` controls the surface variation so
/// re-realizing the same intent yields a paraphrase, not a copy.
pub fn realize(intent: &IntentKey, rng: &mut Rng) -> String {
    let d = &DOMAINS[intent.domain as usize % DOMAINS.len()];
    let e = d.entities[intent.entity as usize % d.entities.len()];
    let a = d.attributes[intent.attribute as usize % d.attributes.len()];
    let base = if intent.class == 255 {
        let f = vocabulary::FREEFORM
            [intent.variant as usize % vocabulary::FREEFORM.len()];
        f.to_string()
    } else {
        // Pick a template within the intent's class. Mostly the intent's
        // canonical wording (duplicate pairs in Quora usually share
        // substantial phrasing), sometimes a sibling template — that's the
        // paraphrase diversity.
        let class_templates: Vec<&vocabulary::Template> = TEMPLATES
            .iter()
            .filter(|t| t.class == intent.class)
            .collect();
        let canonical = (intent.entity as usize * 7
            + intent.attribute as usize * 13
            + intent.domain as usize)
            % class_templates.len();
        let idx = if rng.chance(0.3) {
            rng.usize(class_templates.len())
        } else {
            canonical
        };
        class_templates[idx].text.to_string()
    };
    let p_pair = POLARITY[(intent.entity as usize + intent.attribute as usize) % POLARITY.len()];
    let p = match intent.polarity {
        0 => p_pair[0],
        1 => p_pair[1],
        _ => "notable",
    };
    let mut text = base
        .replace("{e}", e)
        .replace("{a}", a)
        .replace("{p}", p)
        .replace("{d}", d.name);

    // surface paraphrase transforms
    if rng.chance(0.35) {
        text = format!("{} {}", rng.choose(PREFIX_FILLERS), text);
    }
    if rng.chance(0.2) {
        text = format!("{} {}", text.trim_end_matches('?'), rng.choose(SUFFIX_FILLERS));
    }
    if rng.chance(0.5) {
        text = apply_synonyms(&text, rng);
    }
    text
}

/// Word-level synonym substitution (keeps most tokens shared).
fn apply_synonyms(text: &str, rng: &mut Rng) -> String {
    let mut words: Vec<String> = text.split(' ').map(|w| w.to_string()).collect();
    for w in &mut words {
        for group in SYNONYMS {
            if group.contains(&w.as_str()) && rng.chance(0.5) {
                *w = rng.choose(group).to_string();
                break;
            }
        }
    }
    words.join(" ")
}

/// A canonical "ideal" response text for an intent — what the Big LLM
/// "knows". Deterministic per intent; used as cache content and as the
/// reference the quality model measures against.
pub fn ideal_response(intent: &IntentKey) -> String {
    let d = &DOMAINS[intent.domain as usize % DOMAINS.len()];
    let e = d.entities[intent.entity as usize % d.entities.len()];
    let a = d.attributes[intent.attribute as usize % d.attributes.len()];
    let stance = match intent.polarity {
        0 => "the upsides dominate",
        1 => "the downsides dominate",
        _ => "the evidence is mixed",
    };
    format!(
        "regarding {a} of {e} in {d}: {stance}; key factors include context, \
consistency, and tradeoffs specific to {e}",
        d = d.name
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(domain: u16, entity: u16, attribute: u16, polarity: u8, class: u8) -> IntentKey {
        IntentKey { domain, entity, attribute, polarity, class, variant: 0 }
    }

    #[test]
    fn affinity_identity() {
        let a = key(1, 2, 3, 0, 0);
        assert_eq!(intent_affinity(&a, &a), 1.0);
    }

    #[test]
    fn polarity_flip_destroys_affinity() {
        let a = key(1, 2, 3, 0, 0);
        let b = key(1, 2, 3, 1, 0);
        assert!(intent_affinity(&a, &b) < 0.5);
    }

    #[test]
    fn cross_domain_near_zero() {
        let a = key(0, 2, 3, 0, 0);
        let b = key(5, 2, 3, 0, 0);
        assert!(intent_affinity(&a, &b) <= 0.2);
    }

    #[test]
    fn affinity_ordering_is_sane() {
        let base = key(1, 2, 3, 0, 0);
        let same_diff_class = key(1, 2, 3, 0, 1);
        let diff_attr = key(1, 2, 4, 0, 0);
        let diff_entity = key(1, 5, 3, 0, 0);
        let flipped = key(1, 2, 3, 1, 0);
        let a1 = intent_affinity(&base, &same_diff_class);
        let a2 = intent_affinity(&base, &diff_attr);
        let a3 = intent_affinity(&base, &diff_entity);
        let a4 = intent_affinity(&base, &flipped);
        assert!(a1 > a2 && a2 > a4, "{a1} {a2} {a4}");
        assert!(a1 > a3, "{a1} {a3}");
    }

    #[test]
    fn realize_same_intent_shares_tokens() {
        let mut rng = Rng::new(1);
        let i = key(0, 1, 2, 0, 0);
        let a = realize(&i, &mut rng);
        let b = realize(&i, &mut rng);
        let wa: std::collections::HashSet<_> = a.split(' ').collect();
        let wb: std::collections::HashSet<_> = b.split(' ').collect();
        let shared = wa.intersection(&wb).count();
        assert!(shared >= 3, "a={a:?} b={b:?}");
    }

    #[test]
    fn realize_includes_entity() {
        let mut rng = Rng::new(2);
        let i = key(0, 1, 2, 0, 1);
        let t = realize(&i, &mut rng);
        assert!(t.contains("rust"), "{t}");
    }

    #[test]
    fn ideal_response_is_deterministic() {
        let i = key(3, 1, 2, 1, 0);
        assert_eq!(ideal_response(&i), ideal_response(&i));
        assert!(ideal_response(&i).contains("downsides"));
    }
}
