//! Cluster-mode drills: shard-ring properties, WAL-shipping replication,
//! and the kill-a-shard failover ladder (owner → bounded-staleness replica
//! read → cache-bypass miss), in-process and across real processes.
//!
//! Backends in these drills are never "restarted" on the same port — std
//! offers no SO_REUSEADDR, so a rebound listener would collide with its own
//! TIME_WAIT sockets. Instead the topology names a tiny test-local TCP
//! proxy whose listener outlives the kill; rejoin re-points the proxy at
//! the reborn owner's fresh port.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use tweakllm::baselines::MockLlm;
use tweakllm::cache::query_key;
use tweakllm::cluster::ring::DEFAULT_VNODES;
use tweakllm::cluster::{
    ClusterServer, HealthState, ReplicaListener, ShardRing, ShardSpec, Shipper, Topology,
};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Pathway, ReadMode, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::server::{Client, HttpServer, Server, Shutdown};
use tweakllm::util::rng::hash_bytes;

const WAIT: Duration = Duration::from_secs(10);

fn wait_for(what: &str, mut ok: impl FnMut() -> bool) {
    let t0 = Instant::now();
    loop {
        if ok() {
            return;
        }
        assert!(t0.elapsed() < WAIT, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(20));
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tweakllm-cluster-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Prime query: six disjoint synthetic words (same scheme as the fault
/// drills) — guaranteed misses against each other with the bow embedder.
fn prime(topic: usize) -> String {
    format!("q{topic}a q{topic}b q{topic}c q{topic}d q{topic}e q{topic}f")
}

fn free_addr() -> String {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().to_string()
}

// ---------------------------------------------------------------------------
// Shard-ring properties
// ---------------------------------------------------------------------------

#[test]
fn resharding_moves_a_bounded_fraction_of_keys_onto_the_new_shard() {
    let keys: Vec<u64> = (0..10_000u64).map(|k| hash_bytes(&k.to_le_bytes())).collect();
    for n in 1..=5 {
        let before = ShardRing::new(n, DEFAULT_VNODES);
        let after = ShardRing::new(n + 1, DEFAULT_VNODES);
        let mut moved = 0usize;
        for &k in &keys {
            let (a, b) = (before.route(k), after.route(k));
            if a != b {
                moved += 1;
                // Consistent hashing: keys only ever move TO the new shard.
                assert_eq!(b, n, "key moved shard {a} -> {b}, not to the new shard {n}");
            }
        }
        let expected = keys.len() / (n + 1);
        assert!(moved > 0, "growing {n} -> {} must move some keys", n + 1);
        assert!(
            moved <= expected * 3 / 2,
            "growing {n} -> {}: moved {moved} keys, expected ~{expected} (1/{})",
            n + 1,
            n + 1
        );
    }
}

#[test]
fn ring_is_restart_stable_and_roughly_balanced_on_query_keys() {
    let ring = ShardRing::new(4, DEFAULT_VNODES);
    let rebuilt = ShardRing::new(4, DEFAULT_VNODES);
    let mut counts = [0usize; 4];
    for t in 0..4000 {
        let key = query_key(&format!("synthetic question {t} about topic {}", t % 97));
        assert_eq!(ring.route(key), rebuilt.route(key), "routing must survive a restart");
        counts[ring.route(key)] += 1;
    }
    // query_key canonicalizes text, so the router and every owner's exact
    // path agree on identity regardless of case/whitespace.
    assert_eq!(
        ring.route(query_key("What IS a shard ring")),
        ring.route(query_key("  what is a   SHARD ring "))
    );
    let min = *counts.iter().min().unwrap();
    let max = *counts.iter().max().unwrap();
    assert!(min > 0, "a shard got no load: {counts:?}");
    assert!(max < min * 3, "virtual nodes should keep load roughly even: {counts:?}");
}

// ---------------------------------------------------------------------------
// In-process node harness
// ---------------------------------------------------------------------------

struct Node {
    _engine: Engine,
    handle: EngineHandle,
    health: HealthState,
    addr: String,
    stop: Shutdown,
    join: Option<thread::JoinHandle<anyhow::Result<()>>>,
}

fn mock_router(data_dir: Option<PathBuf>) -> anyhow::Result<Router> {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    if let Some(d) = &data_dir {
        cfg.persist.data_dir = d.to_string_lossy().to_string();
        cfg.persist.wal_fsync = false;
    }
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    let mut r = Router::with_models(
        embedder,
        Box::new(MockLlm::new("big")),
        Box::new(MockLlm::new("small")),
        cfg,
    );
    r.enable_persistence()?;
    Ok(r)
}

fn start_node(role: &str, data_dir: Option<PathBuf>) -> Node {
    let health = HealthState::new(role);
    let (engine, handle) =
        Engine::start(move || mock_router(data_dir)).expect("engine start");
    let server = Server::bind("127.0.0.1:0", handle.clone())
        .expect("bind")
        .with_health(health.extra());
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.shutdown_handle().unwrap();
    let join = thread::spawn(move || server.serve());
    Node { _engine: engine, handle, health, addr, stop, join: Some(join) }
}

impl Node {
    /// Kill the TCP front end (the engine stays up, as a replica's would).
    /// Sleeps past the connection threads' poll tick so every accepted
    /// socket is really gone before the drill continues.
    fn kill_front_end(&mut self) {
        self.stop.signal();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        thread::sleep(Duration::from_millis(400));
    }
}

/// Minimal TCP forwarder standing in front of a backend so drills can kill
/// and later resurrect it on a fresh port while the topology keeps one
/// stable address (see module docs for why rebinding is off the table).
struct Proxy {
    addr: String,
    target: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl Proxy {
    fn start(target: &str) -> Proxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let target = Arc::new(Mutex::new(target.to_string()));
        let stop = Arc::new(AtomicBool::new(false));
        let (t2, s2) = (Arc::clone(&target), Arc::clone(&stop));
        let join = thread::spawn(move || {
            for conn in listener.incoming() {
                if s2.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(client) = conn else { continue };
                let upstream_addr = t2.lock().unwrap().clone();
                // Dead target: drop the client (EOF), the router's breaker
                // sees a connection-level failure and fails over.
                let Ok(upstream) = TcpStream::connect(&upstream_addr) else { continue };
                let (c2, u2) = (client.try_clone().unwrap(), upstream.try_clone().unwrap());
                thread::spawn(move || pipe(client, upstream));
                thread::spawn(move || pipe(u2, c2));
            }
        });
        Proxy { addr, target, stop, join: Some(join) }
    }

    fn retarget(&self, target: &str) {
        *self.target.lock().unwrap() = target.to_string();
    }
}

impl Drop for Proxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn pipe(mut from: TcpStream, mut to: TcpStream) {
    let _ = std::io::copy(&mut from, &mut to);
    let _ = to.shutdown(std::net::Shutdown::Both);
    let _ = from.shutdown(std::net::Shutdown::Both);
}

struct ClusterUnderTest {
    owner: Node,
    replica: Node,
    listener: ReplicaListener,
    _shipper: Shipper,
    proxy: Proxy,
    router_addr: String,
    router_stop: Shutdown,
    _router_join: thread::JoinHandle<anyhow::Result<()>>,
}

/// One-shard cluster: owner (durable, shipping its WAL), replica (applying
/// it), and a router fronting both through the bounded-staleness ladder.
fn start_cluster(tag: &str, max_staleness_ms: u64) -> (ClusterUnderTest, PathBuf) {
    let dir = tmp_dir(tag);
    let owner = start_node("owner", Some(dir.clone()));
    let replica = start_node("replica", None);
    let listener =
        ReplicaListener::start("127.0.0.1:0", replica.handle.clone(), replica.health.clone())
            .expect("replication listener");
    let shipper =
        Shipper::start(dir.clone(), &listener.local_addr().to_string(), owner.health.clone());
    let proxy = Proxy::start(&owner.addr);
    let topology = Topology {
        max_staleness_ms,
        epoch: 1,
        vnodes: 32,
        shards: vec![ShardSpec {
            owner: proxy.addr.clone(),
            replica: Some(replica.addr.clone()),
        }],
    };
    let cluster =
        ClusterServer::bind("127.0.0.1:0", topology, &Config::paper()).expect("router bind");
    let router_addr = cluster.local_addr().unwrap().to_string();
    let router_stop = cluster.shutdown_handle().unwrap();
    let join = thread::spawn(move || cluster.serve());
    (
        ClusterUnderTest {
            owner,
            replica,
            listener,
            _shipper: shipper,
            proxy,
            router_addr,
            router_stop,
            _router_join: join,
        },
        dir,
    )
}

// ---------------------------------------------------------------------------
// Read modes and the health verb
// ---------------------------------------------------------------------------

#[test]
fn replica_read_and_bypass_modes_never_mutate_the_cache() {
    let node = start_node("standalone", None);
    let mut c = Client::connect(&node.addr).unwrap();
    let r = c.query_mode(&prime(1), "replica_read").unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
    let r = c.query_mode(&prime(2), "bypass").unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
    assert_eq!(node.handle.stats().unwrap().cache_size, 0, "read modes must not insert");

    let r = c.query(&prime(3)).unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
    assert_eq!(node.handle.stats().unwrap().cache_size, 1);
    // replica_read still serves hits — it only refuses to mutate.
    let r = c.query_mode(&prime(3), "replica_read").unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "exact_hit");
    // ...and bypass skips even a present entry: fresh generation.
    let r = c.query_mode(&prime(3), "bypass").unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");

    let r = c.query_mode("anything", "warp").unwrap();
    assert!(r.opt("error").is_some(), "unknown modes must be refused");
    node.stop.signal();
}

#[test]
fn health_verb_reports_role_and_replication_position() {
    let node = start_node("owner", None);
    node.health.update(|h| {
        h.shipped_gen = 2;
        h.shipped_seq = 9;
        h.connected = true;
    });
    let mut c = Client::connect(&node.addr).unwrap();
    let h = c.health().unwrap();
    assert!(h.get("ok").unwrap().bool().unwrap());
    assert_eq!(h.get("role").unwrap().str().unwrap(), "owner");
    let r = h.get("replication").unwrap();
    assert_eq!(r.get("shipped_gen").unwrap().usize().unwrap(), 2);
    assert_eq!(r.get("shipped_seq").unwrap().usize().unwrap(), 9);
    assert!(r.get("connected").unwrap().bool().unwrap());
    assert_eq!(r.get("staleness_ms").unwrap().usize().unwrap(), 0);
    // Engine-side fields ride along in the same reply.
    assert!(h.opt("breaker_big").is_some());
    assert!(h.opt("cache_size").is_some());
    node.stop.signal();
}

#[test]
fn http_healthz_answers_with_role_and_ok() {
    let node = start_node("replica", None);
    let http = HttpServer::bind("127.0.0.1:0", node.handle.clone())
        .unwrap()
        .with_health(node.health.extra());
    let addr = http.local_addr().unwrap().to_string();
    let stop = http.shutdown_handle().unwrap();
    let join = thread::spawn(move || http.serve());

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut got = String::new();
    let mut buf = [0u8; 4096];
    loop {
        match s.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => got.push_str(&String::from_utf8_lossy(&buf[..n])),
        }
    }
    assert!(got.starts_with("HTTP/1.1 200"), "{got}");
    assert!(got.contains("\"role\""), "{got}");
    assert!(got.contains("replica"), "{got}");
    stop.signal();
    node.stop.signal();
    let _ = join.join();
}

// ---------------------------------------------------------------------------
// WAL shipping
// ---------------------------------------------------------------------------

#[test]
fn wal_shipping_converges_and_resumes_without_duplication() {
    let dir = tmp_dir("ship-converge");
    let owner = start_node("owner", Some(dir.clone()));
    let replica = start_node("replica", None);
    let listener =
        ReplicaListener::start("127.0.0.1:0", replica.handle.clone(), replica.health.clone())
            .unwrap();
    let target = listener.local_addr().to_string();
    let shipper = Shipper::start(dir.clone(), &target, owner.health.clone());

    for t in 0..4 {
        assert_eq!(owner.handle.request(&prime(t)).unwrap().pathway, Pathway::Miss);
    }
    wait_for("replica to apply 4 shipped inserts", || {
        replica.handle.stats().unwrap().cache_size == 4
    });
    // Acks drain: the owner's measured position catches its shipped one.
    wait_for("acks to drain", || {
        let h = owner.health.snapshot();
        h.connected && (h.acked_gen, h.acked_seq) == (h.shipped_gen, h.shipped_seq)
    });
    assert_eq!(replica.health.snapshot().staleness_ms(), 0);

    // The replicated entry serves as an exact hit under replica_read, and
    // the answer is byte-identical to what the owner cached.
    let owned = owner.handle.request(&prime(0)).unwrap();
    assert_eq!(owned.pathway, Pathway::ExactHit);
    let r = replica.handle.request_mode(&prime(0), ReadMode::ReplicaRead).unwrap();
    assert_eq!(r.pathway, Pathway::ExactHit);
    assert_eq!(r.text, owned.text);

    // Drop the session mid-stream; a new shipper must resume from the
    // replica's acked position (HELLO), not re-apply history.
    shipper.stop();
    for t in 4..6 {
        owner.handle.request(&prime(t)).unwrap();
    }
    let _shipper2 = Shipper::start(dir.clone(), &target, owner.health.clone());
    wait_for("resumed session to ship the 2 new inserts", || {
        replica.handle.stats().unwrap().cache_size == 6
    });
    thread::sleep(Duration::from_millis(200)); // give duplicates a chance to surface
    assert_eq!(replica.handle.stats().unwrap().cache_size, 6, "resume must not re-apply");

    owner.stop.signal();
    replica.stop.signal();
    drop(listener);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Failover drills
// ---------------------------------------------------------------------------

#[test]
fn kill_the_shard_owner_mid_traffic_and_every_request_still_answers() {
    let (mut cluster, dir) = start_cluster("kill-drill", 10_000);
    let mut c = Client::connect(&cluster.router_addr).unwrap();

    for t in 0..5 {
        let r = c.query(&prime(t)).unwrap();
        assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
        assert_eq!(r.get("served_by").unwrap().str().unwrap(), "owner");
        assert_eq!(r.get("shard").unwrap().usize().unwrap(), 0);
    }
    wait_for("replication to converge before the kill", || {
        cluster.replica.handle.stats().unwrap().cache_size == 5
    });

    cluster.owner.kill_front_end();

    // Cached repeats survive the owner's death as replica exact hits.
    for t in 0..5 {
        let r = c.query(&prime(t)).unwrap();
        assert!(r.opt("error").is_none(), "{}", r.to_string());
        assert_eq!(r.get("pathway").unwrap().str().unwrap(), "exact_hit");
        assert_eq!(r.get("served_by").unwrap().str().unwrap(), "replica");
        assert!(r.opt("staleness_ms").is_some());
    }
    // A novel query during the outage is generated fresh on the replica
    // and NOT inserted: the entry id space belongs to the owner's WAL.
    let r = c.query(&prime(9)).unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
    assert_eq!(r.get("served_by").unwrap().str().unwrap(), "replica");
    assert_eq!(cluster.replica.handle.stats().unwrap().cache_size, 5);

    // 100% availability, one reply one trace, zero router-level errors.
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("requests").unwrap().usize().unwrap(), 11);
    assert_eq!(stats.get("traces_finished").unwrap().usize().unwrap(), 11);
    assert_eq!(stats.get("errors").unwrap().usize().unwrap(), 0);
    assert_eq!(stats.get("owner_served").unwrap().usize().unwrap(), 5);
    assert_eq!(stats.get("replica_served").unwrap().usize().unwrap(), 6);
    assert!(stats.get("failovers").unwrap().usize().unwrap() >= 6);

    // Rejoin on a fresh port behind the stable proxy address: the breaker
    // half-opens after its cool-down and traffic returns to the owner.
    let reborn = Server::bind("127.0.0.1:0", cluster.owner.handle.clone())
        .unwrap()
        .with_health(cluster.owner.health.extra());
    let reborn_addr = reborn.local_addr().unwrap().to_string();
    let reborn_stop = reborn.shutdown_handle().unwrap();
    let reborn_join = thread::spawn(move || reborn.serve());
    cluster.proxy.retarget(&reborn_addr);
    wait_for("traffic to return to the rejoined owner", || {
        let r = c.query(&prime(0)).unwrap();
        r.get("served_by").unwrap().str().unwrap() == "owner"
    });
    // No duplication anywhere after the rejoin.
    assert_eq!(cluster.owner.handle.stats().unwrap().cache_size, 5);
    assert_eq!(cluster.replica.handle.stats().unwrap().cache_size, 5);

    reborn_stop.signal();
    let _ = reborn_join.join();
    cluster.router_stop.signal();
    cluster.replica.stop.signal();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_replica_degrades_to_bypass_until_it_catches_up() {
    let (mut cluster, dir) = start_cluster("stale-drill", 150);
    let mut c = Client::connect(&cluster.router_addr).unwrap();

    for t in 0..2 {
        c.query(&prime(t)).unwrap();
    }
    wait_for("replication to converge", || {
        cluster.replica.handle.stats().unwrap().cache_size == 2
    });

    // Freeze the apply loop, then write through the owner: the record
    // ships but cannot apply, so measured staleness starts growing.
    cluster.listener.set_apply_paused(true);
    let r = c.query(&prime(2)).unwrap();
    assert_eq!(r.get("served_by").unwrap().str().unwrap(), "owner");
    wait_for("the replica to notice it is behind", || {
        cluster.replica.health.snapshot().staleness_ms() > 0
    });
    thread::sleep(Duration::from_millis(300)); // grow past max_staleness_ms=150

    cluster.owner.kill_front_end();

    // Too stale for cache hits: the cached prime(0) must NOT be served
    // from the replica's cache — the request degrades to a fresh bypass
    // generation instead. Stale text is never served.
    let r = c.query(&prime(0)).unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
    assert_eq!(r.get("served_by").unwrap().str().unwrap(), "replica_bypass");
    assert!(r.get("staleness_ms").unwrap().usize().unwrap() > 150);

    // Unfreeze: the backlog applies, staleness collapses to zero, and the
    // same query is once again a replica exact hit.
    cluster.listener.set_apply_paused(false);
    wait_for("the replica to catch up", || {
        let h = cluster.replica.health.snapshot();
        cluster.replica.handle.stats().unwrap().cache_size == 3 && h.staleness_ms() == 0
    });
    wait_for("replica reads to resume", || {
        let r = c.query(&prime(0)).unwrap();
        r.get("served_by").unwrap().str().unwrap() == "replica"
            && r.get("pathway").unwrap().str().unwrap() == "exact_hit"
    });

    cluster.router_stop.signal();
    cluster.replica.stop.signal();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Real-process kill drill
// ---------------------------------------------------------------------------

struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn spawn_serve(args: &[&str]) -> ChildGuard {
    ChildGuard(
        Command::new(env!("CARGO_BIN_EXE_tweakllm"))
            .arg("serve")
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn tweakllm serve"),
    )
}

fn wait_healthy(addr: &str) {
    wait_for(&format!("{addr} to answer its health verb"), || {
        Client::connect(addr)
            .and_then(|mut c| c.health())
            .map(|h| h.opt("ok").is_some())
            .unwrap_or(false)
    })
}

fn remote_cache_size(addr: &str) -> usize {
    Client::connect(addr)
        .and_then(|mut c| c.stats())
        .ok()
        .and_then(|s| s.opt("cache_size").and_then(|v| v.usize().ok()))
        .unwrap_or(usize::MAX)
}

/// The tentpole drill against real processes: SIGKILL the shard owner
/// mid-traffic, assert the router keeps answering (replica reads), then
/// rejoin the owner from its surviving data directory and assert nothing
/// was lost or duplicated.
#[test]
fn process_kill_drill_full_availability_and_clean_rejoin() {
    let dir = tmp_dir("proc-drill");
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("data");
    let data_str = data.to_string_lossy().to_string();

    let owner_addr = free_addr();
    let replica_addr = free_addr();
    let repl_listen = free_addr();
    let router_addr = free_addr();
    let proxy = Proxy::start(&owner_addr);

    let topo_path = dir.join("topology.toml");
    std::fs::write(
        &topo_path,
        format!(
            "[cluster]\nmax_staleness_ms = 10000\nepoch = 1\nvnodes = 32\n\n\
             [[shard]]\nowner = \"{}\"\nreplica = \"{replica_addr}\"\n",
            proxy.addr
        ),
    )
    .unwrap();

    let _replica = spawn_serve(&[
        "--mock=true",
        "--addr",
        &replica_addr,
        "--replication-listen",
        &repl_listen,
    ]);
    let owner = spawn_serve(&[
        "--mock=true",
        "--addr",
        &owner_addr,
        "--data-dir",
        &data_str,
        "--ship-to",
        &repl_listen,
    ]);
    let _router =
        spawn_serve(&["--cluster", &topo_path.to_string_lossy(), "--addr", &router_addr]);
    wait_healthy(&owner_addr);
    wait_healthy(&replica_addr);
    wait_healthy(&router_addr);

    let mut c = Client::connect(&router_addr).unwrap();
    for t in 0..6 {
        let r = c.query(&prime(t)).unwrap();
        assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss", "{}", r.to_string());
        assert_eq!(r.get("served_by").unwrap().str().unwrap(), "owner");
    }
    wait_for("the replica process to converge", || remote_cache_size(&replica_addr) == 6);

    drop(owner); // SIGKILL mid-traffic

    for t in 0..6 {
        let r = c.query(&prime(t)).unwrap();
        assert!(r.opt("error").is_none(), "{}", r.to_string());
        assert_eq!(r.get("pathway").unwrap().str().unwrap(), "exact_hit");
        assert_eq!(r.get("served_by").unwrap().str().unwrap(), "replica");
    }
    let r = c.query(&prime(9)).unwrap();
    assert_eq!(r.get("pathway").unwrap().str().unwrap(), "miss");
    assert_eq!(r.get("served_by").unwrap().str().unwrap(), "replica");

    let stats = c.stats().unwrap();
    assert_eq!(stats.get("requests").unwrap().usize().unwrap(), 13);
    assert_eq!(stats.get("traces_finished").unwrap().usize().unwrap(), 13);
    assert_eq!(stats.get("errors").unwrap().usize().unwrap(), 0);

    // Rejoin: a new owner process on a fresh port recovers the WAL, the
    // shipper resumes from the replica's acked position, and the router's
    // breaker heals back to owner-served traffic.
    let owner2_addr = free_addr();
    let _owner2 = spawn_serve(&[
        "--mock=true",
        "--addr",
        &owner2_addr,
        "--data-dir",
        &data_str,
        "--ship-to",
        &repl_listen,
    ]);
    wait_healthy(&owner2_addr);
    assert_eq!(remote_cache_size(&owner2_addr), 6, "recovery must restore every entry once");
    proxy.retarget(&owner2_addr);
    wait_for("traffic to return to the rejoined owner", || {
        let r = c.query(&prime(0)).unwrap();
        r.get("served_by").unwrap().str().unwrap() == "owner"
    });
    thread::sleep(Duration::from_millis(300)); // let the resumed shipper settle
    assert_eq!(remote_cache_size(&replica_addr), 6, "rejoin must not duplicate entries");

    let _ = std::fs::remove_dir_all(&dir);
}
