//! Persistence integration tests: kill-and-restart recovery through the
//! full router (mock models + native embedder, no artifacts needed),
//! eviction/tombstone round-trips, and crash-shaped failure injection.

use std::path::PathBuf;

use tweakllm::baselines::MockLlm;
use tweakllm::cache::{EvictionPolicy, IndexKind, PersistConfig, SemanticCache};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Pathway, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::util::normalize;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tweakllm-itest-{}-{}",
        std::process::id(),
        tag
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn persist_config(tag: &str) -> (Config, PathBuf) {
    let dir = tmp_dir(tag);
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.persist.data_dir = dir.to_string_lossy().to_string();
    (cfg, dir)
}

fn make_router(cfg: Config) -> Router {
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    let mut r = Router::with_models(
        embedder,
        Box::new(MockLlm::new("big")),
        Box::new(MockLlm::new("small")),
        cfg,
    );
    r.enable_persistence().expect("persistence");
    r
}

fn unit_vec(seed: u64, dim: usize) -> Vec<f32> {
    let mut rng = tweakllm::util::Rng::new(seed);
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    normalize(&mut v);
    v
}

/// The acceptance scenario: populate through the router, kill the process
/// abruptly (no graceful snapshot — drop recovers nothing, the WAL is the
/// only durable state), restart on the same data dir, and the recovered
/// cache must answer identically: same pathways, similarities, entry ids.
#[test]
fn kill_and_restart_answers_identically() {
    let (cfg, dir) = persist_config("killrestart");

    let corpus = [
        "why is coffee good for health?",
        "write a poem about glaciers",
        "explain the rust borrow checker",
        "what is the capital of iceland",
        "how do vaccines train immunity",
    ];
    // Paraphrase probes: tweak hits (which never mutate cache contents),
    // so probing twice is side-effect-free at the entry level.
    let probes = [
        "why is coffee great for health?",
        "write a poem about a glacier",
        "explain the rust borrow checker rules",
        "what is the capital city of iceland",
        "how do vaccines train our immunity",
    ];

    let before: Vec<(Pathway, Option<f32>, Option<usize>)>;
    let len_before;
    {
        let mut r = make_router(cfg.clone());
        assert_eq!(r.recovery.as_ref().unwrap().recovered_entries, 0);
        for q in &corpus {
            let resp = r.handle(q).unwrap();
            assert_eq!(resp.pathway, Pathway::Miss);
        }
        // Warm pass: any probe that misses caches itself here, so the
        // baseline pass below is deterministic hits — re-running it (before
        // or after restart) cannot mutate cache contents.
        for q in &probes {
            r.handle(q).unwrap();
        }
        len_before = r.cache().len();
        before = probes
            .iter()
            .map(|q| {
                let resp = r.handle(q).unwrap();
                (resp.pathway, resp.similarity, resp.cache_entry)
            })
            .collect();
        assert!(
            before.iter().all(|(p, _, _)| *p == Pathway::TweakHit),
            "baseline probes must all hit: {before:?}"
        );
        assert_eq!(r.cache().len(), len_before, "baseline pass mutated the cache");
        // Hard kill: drop the router with NO snapshot. Recovery must come
        // entirely from the WAL.
        drop(r);
    }
    assert!(
        !std::fs::read_dir(&dir).unwrap().any(|e| {
            e.unwrap().file_name().to_string_lossy().ends_with(".snap")
        }),
        "test bug: a snapshot exists, crash recovery would not be exercised"
    );

    let mut r = make_router(cfg);
    let report = r.recovery.clone().unwrap();
    assert_eq!(report.recovered_entries as usize, len_before);
    assert_eq!(r.cache().len(), len_before);
    for (q, (pathway, similarity, entry)) in probes.iter().zip(&before) {
        let resp = r.handle(q).unwrap();
        assert_eq!(resp.pathway, *pathway, "pathway changed for {q:?}");
        assert_eq!(resp.similarity, *similarity, "similarity changed for {q:?}");
        assert_eq!(resp.cache_entry, *entry, "entry id changed for {q:?}");
    }
    // The recovered entries carry the original response texts.
    for q in &corpus {
        let resp = r.handle(q).unwrap();
        assert!(resp.text.contains(&format!("answer about: {q}")), "{}", resp.text);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same scenario but through a graceful shutdown snapshot: restart should
/// replay zero WAL ops and still answer identically.
#[test]
fn graceful_snapshot_restart_replays_nothing() {
    let (cfg, dir) = persist_config("graceful");
    let probe = "why is coffee great for health?";
    let before;
    {
        let mut r = make_router(cfg.clone());
        r.handle("why is coffee good for health?").unwrap();
        r.handle("explain the rust borrow checker").unwrap();
        let resp = r.handle(probe).unwrap();
        before = (resp.pathway, resp.similarity, resp.cache_entry);
        let generation = r.snapshot().unwrap();
        assert_eq!(generation, Some(1));
    }
    let mut r = make_router(cfg);
    let report = r.recovery.clone().unwrap();
    assert_eq!(report.replayed_ops, 0, "snapshot should have folded the WAL");
    assert_eq!(report.recovered_entries, 2);
    let resp = r.handle(probe).unwrap();
    assert_eq!((resp.pathway, resp.similarity, resp.cache_entry), before);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: evict under LRU at capacity, snapshot, replay — tombstoned
/// ids never match again and `len()` / stats survive recovery. Exercised
/// both through a snapshot and through pure WAL replay.
#[test]
fn eviction_tombstones_roundtrip_through_persistence() {
    for (tag, take_snapshot) in [("evict-snap", true), ("evict-wal", false)] {
        let dir = tmp_dir(tag);
        let pcfg = PersistConfig {
            data_dir: dir.to_string_lossy().to_string(),
            wal_fsync: false,
            compact_bytes: u64::MAX,
            fsync_batch_ms: 0,
        };
        let dim = 16;
        let vs: Vec<Vec<f32>> = (0..6).map(|i| unit_vec(100 + i as u64, dim)).collect();
        {
            let (mut c, _) = SemanticCache::open_persistent(
                dim,
                IndexKind::Flat,
                EvictionPolicy::Lru,
                4,
                true,
                &pcfg,
            )
            .unwrap();
            for (i, v) in vs.iter().enumerate() {
                c.insert(&format!("q{i}"), &format!("r{i}"), v.clone());
            }
            // Capacity 4, 6 inserts: ids 0 and 1 are evicted (LRU, no hits).
            assert_eq!(c.len(), 4);
            assert_eq!(c.stats().evictions, 2);
            if take_snapshot {
                c.compact_now().unwrap();
            }
        }
        let (mut c, report) = SemanticCache::open_persistent(
            dim,
            IndexKind::Flat,
            EvictionPolicy::Lru,
            4,
            true,
            &pcfg,
        )
        .unwrap();
        assert_eq!(report.recovered_entries, 4, "{tag}");
        assert_eq!(c.len(), 4, "{tag}: len must survive recovery");
        assert_eq!(c.stats().inserts, 6, "{tag}: stats must survive recovery");
        assert_eq!(c.stats().evictions, 2, "{tag}");
        for dead in 0..2usize {
            assert!(c.entry(dead).is_none(), "{tag}: evicted id {dead} resurrected");
            assert!(
                c.lookup_exact(&format!("q{dead}")).is_none(),
                "{tag}: evicted exact key q{dead} resurrected"
            );
            let hits = c.search(&vs[dead], 6);
            assert!(
                hits.iter().all(|h| h.id != dead),
                "{tag}: tombstoned id {dead} matched again: {hits:?}"
            );
        }
        // Survivors still match themselves with their original ids.
        for live in 2..6usize {
            assert_eq!(c.search(&vs[live], 1)[0].id, live, "{tag}");
            assert_eq!(
                c.entry(live).unwrap().response_text,
                format!("r{live}"),
                "{tag}"
            );
        }
        // Recovery preserved LRU bookkeeping: the next insert over capacity
        // evicts the least-recently-used survivor (id 2), not an arbitrary
        // one.
        c.insert("q6", "r6", unit_vec(106, dim));
        assert!(c.entry(2).is_none(), "{tag}: LRU order lost in recovery");
        assert!(c.entry(3).is_some(), "{tag}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A torn WAL tail (crash mid-append) is dropped; every complete record
/// before it is recovered.
#[test]
fn torn_wal_tail_is_dropped_not_fatal() {
    let dir = tmp_dir("torn");
    let pcfg = PersistConfig {
        data_dir: dir.to_string_lossy().to_string(),
        wal_fsync: false,
        compact_bytes: u64::MAX,
        fsync_batch_ms: 0,
    };
    let dim = 8;
    {
        let (mut c, _) = SemanticCache::open_persistent(
            dim,
            IndexKind::Flat,
            EvictionPolicy::None,
            usize::MAX,
            false,
            &pcfg,
        )
        .unwrap();
        for i in 0..5 {
            c.insert(&format!("q{i}"), "r", unit_vec(200 + i as u64, dim));
        }
    }
    // Simulate a crash mid-append: garbage at the end of the WAL.
    let wal = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| p.to_string_lossy().ends_with(".log"))
        .expect("WAL file");
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
    f.write_all(&[1, 255, 0, 0, 42, 42]).unwrap();
    drop(f);

    let (c, report) = SemanticCache::open_persistent(
        dim,
        IndexKind::Flat,
        EvictionPolicy::None,
        usize::MAX,
        false,
        &pcfg,
    )
    .unwrap();
    assert!(report.torn_tail);
    assert_eq!(c.len(), 5);
    // And the truncated WAL accepts appends again (generation unchanged).
    assert_eq!(report.generation, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: compaction hands the WAL off crash-safely under an attached
/// shipper. The generation-bump record is appended to the old WAL only
/// after the new snapshot is durable, the previous generation's file is
/// retained so a live tailer can follow the handoff, and a torn tail on
/// the new WAL right after the handoff costs only the torn record — for
/// recovery AND for a tailer resuming at the replica's acked position.
#[test]
fn torn_tail_during_compaction_handoff_recovers() {
    use tweakllm::cache::persist::WalTailer;
    use tweakllm::cache::WalOp;

    let dir = tmp_dir("torn-compact");
    let pcfg = PersistConfig {
        data_dir: dir.to_string_lossy().to_string(),
        wal_fsync: false,
        compact_bytes: u64::MAX,
        fsync_batch_ms: 0,
    };
    let dim = 8;
    {
        let (mut c, _) = SemanticCache::open_persistent(
            dim,
            IndexKind::Flat,
            EvictionPolicy::None,
            usize::MAX,
            false,
            &pcfg,
        )
        .unwrap();
        for i in 0..3 {
            c.insert(&format!("q{i}"), "r", unit_vec(400 + i as u64, dim));
        }
        // A shipper is mid-stream on generation 0 when compaction runs.
        let mut tailer = WalTailer::from_generation_start(&dir, 0);
        assert_eq!(tailer.poll().unwrap().len(), 3);
        assert_eq!(tailer.position(), (0, 3));

        c.compact_now().unwrap(); // generation 0 -> 1
        c.insert("q3", "r", unit_vec(403, dim));
        c.insert("q4", "r", unit_vec(404, dim));

        // The tailer follows the bump into generation 1 without rewinding.
        let recs = tailer.poll().unwrap();
        assert_eq!(recs.len(), 3, "bump + 2 post-compaction inserts");
        assert!(
            matches!(recs[0].op, WalOp::GenBump { next_gen: 1 }),
            "handoff must be announced in the old WAL: {:?}",
            recs[0].op
        );
        assert_eq!(tailer.position(), (1, 2));
    }
    // The pre-handoff WAL stays on disk for tailers that haven't crossed.
    assert!(dir.join("wal-00000000.log").exists(), "old-generation WAL was GC'd");

    // Crash mid-append right after the handoff: garbage tail on the NEW WAL.
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(dir.join("wal-00000001.log"))
        .unwrap();
    f.write_all(&[1, 255, 0, 0, 42, 42]).unwrap();
    drop(f);

    // A tailer resuming from the replica's acked position surfaces exactly
    // the complete records and leaves the torn tail alone.
    let mut resumed = WalTailer::resume(&dir, 1, 1).unwrap();
    let recs = resumed.poll().unwrap();
    assert_eq!(recs.len(), 1, "only the complete post-ack record");
    assert_eq!(resumed.position(), (1, 2));

    // Recovery agrees: snapshot + both generation-1 ops, torn tail dropped.
    let (c, report) = SemanticCache::open_persistent(
        dim,
        IndexKind::Flat,
        EvictionPolicy::None,
        usize::MAX,
        false,
        &pcfg,
    )
    .unwrap();
    assert!(report.torn_tail);
    assert_eq!(report.generation, 1);
    assert_eq!(report.replayed_ops, 2);
    assert_eq!(c.len(), 5);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recovery refuses a cache whose embedder dimension changed: silently
/// serving mis-sized vectors would corrupt every similarity score.
#[test]
fn dim_mismatch_is_an_error() {
    let dir = tmp_dir("dim");
    let pcfg = PersistConfig {
        data_dir: dir.to_string_lossy().to_string(),
        wal_fsync: false,
        compact_bytes: u64::MAX,
        fsync_batch_ms: 0,
    };
    {
        let (mut c, _) = SemanticCache::open_persistent(
            8,
            IndexKind::Flat,
            EvictionPolicy::None,
            usize::MAX,
            false,
            &pcfg,
        )
        .unwrap();
        c.insert("q", "r", unit_vec(300, 8));
        c.compact_now().unwrap();
    }
    let err = SemanticCache::open_persistent(
        16,
        IndexKind::Flat,
        EvictionPolicy::None,
        usize::MAX,
        false,
        &pcfg,
    );
    assert!(err.is_err(), "dim mismatch must not recover silently");
    let _ = std::fs::remove_dir_all(&dir);
}
