//! Decode-scheduler integration tests over the real engine + batcher (no
//! artifacts needed): the two PR-4 bug regressions (batch-leftover
//! starvation, duplicate-in-batch double generation), head-of-line
//! unblocking, and the scheduler-on == scheduler-off response-identity
//! gate.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tweakllm::baselines::{FaultPlan, MockLlm};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Pathway, Router};
use tweakllm::cost::TokenUsage;
use tweakllm::faults::FaultMode;
use tweakllm::llm::{LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::util::Rng;

fn base_config() -> Config {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg
}

fn start_engine(cfg: Config, big: MockLlm, small: MockLlm) -> (Engine, EngineHandle) {
    Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg))
    })
    .expect("engine start")
}

/// Regression (batch-leftover starvation): a burst larger than `max_batch`
/// followed by silence must complete in full. The old serve loop flushed at
/// most `max_batch` drained requests and then parked on a blocking `recv`,
/// stranding any leftovers in the batcher forever. Both engine modes are
/// gated — the run-to-completion (scheduler-off) path had the same bug.
fn burst_completes(scheduler_on: bool) {
    let mut cfg = base_config();
    cfg.batcher.max_batch = 2;
    cfg.scheduler.enabled = scheduler_on;
    // A slow Big LLM keeps the engine busy so the burst piles up in the
    // channel and gets ingested into the batcher well past max_batch.
    let big = MockLlm::new("big").with_pace(5, Duration::from_millis(2));
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    let n = 7;
    let (done_tx, done_rx) = mpsc::channel();
    for i in 0..n {
        let h = handle.clone();
        let done = done_tx.clone();
        std::thread::spawn(move || {
            let r = h.request(&format!("burst{i}a burst{i}b burst{i}c burst{i}d"));
            let _ = done.send((i, r));
        });
    }
    drop(done_tx);
    let mut served = 0;
    for _ in 0..n {
        let (i, r) = done_rx
            .recv_timeout(Duration::from_secs(20))
            .unwrap_or_else(|_| panic!("request stranded after {served}/{n} replies"));
        let resp = r.unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(resp.pathway, Pathway::Miss);
        served += 1;
    }
    assert_eq!(served, n);
}

#[test]
fn burst_larger_than_max_batch_completes() {
    burst_completes(true);
}

#[test]
fn burst_larger_than_max_batch_completes_scheduler_off() {
    burst_completes(false);
}

/// Regression (duplicate queries inside one micro-batch): two identical
/// missed queries must pay ONE Big-LLM generation and insert ONE cache row.
/// The old flush ran the exact-match check once for the whole batch before
/// any routing, so both paid a generation and the first insert became an
/// unreachable stale row.
#[test]
fn duplicate_in_batch_pays_one_generation() {
    let cfg = base_config();
    // Slow misses (~120ms): the duplicate pair is guaranteed to be routed
    // while the leader's generation is still in flight.
    let big = MockLlm::new("big").with_pace(60, Duration::from_millis(2));
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    let barrier = std::sync::Arc::new(std::sync::Barrier::new(2));
    let (done_tx, done_rx) = mpsc::channel();
    for _ in 0..2 {
        let h = handle.clone();
        let done = done_tx.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        std::thread::spawn(move || {
            barrier.wait();
            // Same normalized text (whitespace + case fold) on both.
            let _ = done.send(h.request("what is a  B-TREE exactly"));
        });
    }
    let a = done_rx.recv_timeout(Duration::from_secs(20)).unwrap();
    let b = done_rx.recv_timeout(Duration::from_secs(20)).unwrap();
    let (a, b) = (a.unwrap(), b.unwrap());
    assert_eq!(a.text, b.text, "duplicates must share one generation");
    assert_eq!(a.cache_entry, b.cache_entry);

    let stats = handle.stats().unwrap();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.misses, 1, "exactly one Big-LLM generation");
    assert_eq!(stats.exact_hits, 1, "the duplicate reports as an exact hit");
    assert_eq!(stats.cache_size, 1, "no duplicate cache row");
    assert_eq!(stats.coalesced, 1, "second dup coalesced onto the in-flight miss");
}

/// The tentpole behavior: a tweak-hit completes while a slow Big-LLM miss
/// is still decoding (no head-of-line blocking).
#[test]
fn tweak_hit_overtakes_inflight_miss() {
    let cfg = base_config();
    let big = MockLlm::new("big").with_pace(40, Duration::from_millis(2));
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    // Prime an entry for the tweak path (pays one slow generation).
    let prime = handle.request("why is coffee good for health?").unwrap();
    assert_eq!(prime.pathway, Pathway::Miss);

    // Start a slow miss, then a tweak-hit 15ms behind it.
    let h = handle.clone();
    let miss = std::thread::spawn(move || {
        let r = h.request("write a poem about glaciers").unwrap();
        (r, Instant::now())
    });
    std::thread::sleep(Duration::from_millis(15));
    let tweak = handle.request("why is coffee great for health?").unwrap();
    let tweak_done = Instant::now();
    let (miss_resp, miss_done) = miss.join().unwrap();

    assert_eq!(tweak.pathway, Pathway::TweakHit);
    assert_eq!(miss_resp.pathway, Pathway::Miss);
    assert!(tweak_done < miss_done, "tweak-hit must overtake the in-flight miss");
}

/// Regression (coalesced-follower failure fan-out): when a miss leader's
/// generation fails terminally, every coalesced follower must receive the
/// structured error too — the old resolver dropped the followers map entry
/// on the floor, so duplicates hung forever on a reply that never came.
#[test]
fn failed_leader_fans_error_out_to_coalesced_followers() {
    let mut cfg = base_config();
    cfg.faults.miss_retries = 0; // first failure is terminal
    // The leader's doomed generation runs ~100ms before erroring, so the
    // duplicate is guaranteed to be routed — and coalesced — in flight.
    // Only call 0 is scripted to fail: the engine must stay serviceable.
    let big = MockLlm::new("big").with_pace(60, Duration::from_millis(2)).with_fault_plan(
        FaultPlan::new(|call| {
            if call == 0 { FaultMode::FailAfterTokens(50) } else { FaultMode::Healthy }
        }),
    );
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    let barrier = std::sync::Arc::new(std::sync::Barrier::new(2));
    let (done_tx, done_rx) = mpsc::channel();
    for _ in 0..2 {
        let h = handle.clone();
        let done = done_tx.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        std::thread::spawn(move || {
            barrier.wait();
            let _ = done.send(h.request("what is a  B-TREE exactly"));
        });
    }
    for _ in 0..2 {
        let r = done_rx
            .recv_timeout(Duration::from_secs(20))
            .expect("no reply: a coalesced follower hung on its failed leader");
        let err = r.expect_err("the leader's failure must fan out to every rider");
        let msg = format!("{err:#}");
        assert!(msg.contains("generation failed"), "unexpected error shape: {msg}");
        assert!(msg.contains("injected fault"), "root cause must survive fan-out: {msg}");
    }

    let stats = handle.stats().unwrap();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.failed, 2, "leader and follower both settle as failed");
    assert_eq!(stats.coalesced, 1, "the duplicate coalesced before the failure");
    assert_eq!(stats.cache_size, 0, "a failed generation must not insert");

    // The failure was a one-off: the very next miss is served normally.
    let ok = handle.request("fresh topic after the outage").unwrap();
    assert_eq!(ok.pathway, Pathway::Miss);
}

// ---------------------------------------------------------------------------
// Response-identity gate: scheduler-interleaved == sequential, bit for bit.
// ---------------------------------------------------------------------------

/// A mock whose output is drawn from a per-session RNG substream keyed on
/// the full prompt — the same contract `SubstrateLlm` honors. If sessions
/// leaked RNG state across each other, the concurrent (interleaved) run
/// below would diverge from the sequential one.
///
/// With `pool` set, sessions claim slots in a shared collective-advance
/// pool (the credit protocol of `runtime::BatchedDecode`): one "dispatch"
/// per fairness round emits a token for every live slot from its own RNG,
/// and overflow sessions fall back to independent pacing — the mock twin of
/// the batched substrate path, so batched ≡ per-session response identity
/// is gateable end-to-end through the engine.
struct SeededLlm {
    name: String,
    seed: u64,
    steps: usize,
    pool: Option<std::sync::Arc<std::sync::Mutex<SeededBatchPool>>>,
}

struct SeededBatchPool {
    slots: Vec<Option<SeededSlot>>,
    dispatches: u64,
}

struct SeededSlot {
    rng: Rng,
    steps: usize,
    emitted: Vec<String>,
    credits: u32,
}

impl SeededBatchPool {
    fn new(slots: usize) -> SeededBatchPool {
        SeededBatchPool { slots: (0..slots).map(|_| None).collect(), dispatches: 0 }
    }

    fn admit(&mut self, rng: Rng, steps: usize) -> Option<usize> {
        let slot = self.slots.iter().position(|s| s.is_none())?;
        self.slots[slot] =
            Some(SeededSlot { rng, steps, emitted: Vec::new(), credits: 0 });
        Some(slot)
    }

    fn is_done(&self, slot: usize) -> bool {
        match self.slots.get(slot).and_then(|s| s.as_ref()) {
            Some(s) => s.emitted.len() >= s.steps,
            None => true,
        }
    }

    fn advance(&mut self, slot: usize) -> bool {
        {
            let s = self.slots[slot].as_mut().expect("advance on a free slot");
            if s.emitted.len() >= s.steps {
                return false;
            }
            if s.credits > 0 {
                s.credits -= 1;
                return s.emitted.len() < s.steps;
            }
        }
        // collective round: every live slot emits one token from its own rng
        self.dispatches += 1;
        for s in self.slots.iter_mut().flatten() {
            if s.emitted.len() < s.steps {
                let t = format!("t{}", s.rng.range(0, 10_000));
                s.emitted.push(t);
                s.credits += 1;
            }
        }
        let s = self.slots[slot].as_mut().expect("slot vanished mid-round");
        if s.credits > 0 {
            s.credits -= 1;
        }
        s.emitted.len() < s.steps
    }

    fn take(&mut self, slot: usize) -> SeededSlot {
        self.slots[slot].take().expect("take on a free slot")
    }

    fn release(&mut self, slot: usize) {
        if let Some(s) = self.slots.get_mut(slot) {
            *s = None;
        }
    }
}

struct SeededBatchSession {
    pool: std::sync::Arc<std::sync::Mutex<SeededBatchPool>>,
    slot: Option<usize>,
    prefix: String,
    steps: usize,
}

impl LlmSession for SeededBatchSession {
    fn advance(&mut self) -> Result<bool> {
        let slot = self.slot.expect("advance after finish");
        Ok(self.pool.lock().unwrap().advance(slot))
    }

    fn is_done(&self) -> bool {
        match self.slot {
            Some(slot) => self.pool.lock().unwrap().is_done(slot),
            None => true,
        }
    }

    fn finish(mut self: Box<Self>) -> Result<LlmResponse> {
        let slot = self.slot.take().expect("finish twice");
        let s = self.pool.lock().unwrap().take(slot);
        Ok(LlmResponse {
            text: format!("[{}] {}", self.prefix, s.emitted.join(" ")),
            usage: TokenUsage { input_tokens: 1, output_tokens: self.steps },
            restored_tokens: 0,
            prefill_micros: 0,
            decode_micros: 0,
        })
    }
}

impl Drop for SeededBatchSession {
    fn drop(&mut self) {
        if let Some(slot) = self.slot.take() {
            self.pool.lock().unwrap().release(slot);
        }
    }
}

struct SeededSession {
    rng: Rng,
    prefix: String,
    steps: usize,
    emitted: Vec<String>,
}

impl LlmSession for SeededSession {
    fn advance(&mut self) -> Result<bool> {
        if self.emitted.len() < self.steps {
            self.emitted.push(format!("t{}", self.rng.range(0, 10_000)));
        }
        Ok(self.emitted.len() < self.steps)
    }

    fn is_done(&self) -> bool {
        self.emitted.len() >= self.steps
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        Ok(LlmResponse {
            text: format!("[{}] {}", self.prefix, self.emitted.join(" ")),
            usage: TokenUsage { input_tokens: 1, output_tokens: self.steps },
            restored_tokens: 0,
            prefill_micros: 0,
            decode_micros: 0,
        })
    }
}

impl SeededLlm {
    fn new(name: &str, seed: u64, steps: usize) -> SeededLlm {
        SeededLlm { name: name.to_string(), seed, steps, pool: None }
    }

    /// Enable the collective slot pool (the batched mode).
    fn with_batch(mut self, slots: usize) -> SeededLlm {
        self.pool = Some(std::sync::Arc::new(std::sync::Mutex::new(
            SeededBatchPool::new(slots),
        )));
        self
    }

    fn begin(&self, segments: &[&str]) -> Box<dyn LlmSession> {
        let tag = format!("{}/{}", self.name, segments.join("\u{1f}"));
        let rng = Rng::substream(self.seed, &tag);
        let prefix = segments[0].to_string();
        if let Some(pool) = &self.pool {
            if let Some(slot) = pool.lock().unwrap().admit(rng.clone(), self.steps) {
                return Box::new(SeededBatchSession {
                    pool: std::sync::Arc::clone(pool),
                    slot: Some(slot),
                    prefix,
                    steps: self.steps,
                });
            }
            // pool full: overflow onto an independent session — emission is
            // a pure function of (seed, prompt), so streams are unchanged
        }
        Box::new(SeededSession { rng, prefix, steps: self.steps, emitted: Vec::new() })
    }
}

impl LanguageModel for SeededLlm {
    fn name(&self) -> &str {
        &self.name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        let mut s = self.begin(&[query]);
        while s.advance()? {}
        s.finish()
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        let segs = prompt.segments();
        let mut s = self.begin(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        while s.advance()? {}
        s.finish()
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        Ok(self.begin(&[query]))
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        let segs = prompt.segments();
        Ok(self.begin(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>()))
    }
}

/// Run the two-phase workload (sequential primes, then a concurrent mix of
/// tweak-hit paraphrases and fresh misses) and collect query -> (pathway,
/// text). `batch_slots > 0` puts each model behind a collective-advance
/// slot pool of that size (the batched decode mode).
fn run_workload(scheduler_on: bool, batch_slots: usize) -> Vec<(String, String)> {
    let mut cfg = base_config();
    cfg.scheduler.enabled = scheduler_on;
    cfg.exact_match_fast_path = false; // repeats must exercise the tweak path
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        let mut big = SeededLlm::new("big", 11, 12);
        let mut small = SeededLlm::new("small", 13, 3);
        if batch_slots > 0 {
            big = big.with_batch(batch_slots);
            small = small.with_batch(batch_slots);
        }
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg))
    })
    .expect("engine start");

    // Phase 1: sequential primes — identical cache in both runs. Topic
    // word-sets are mutually disjoint so primes never tweak each other.
    for i in 0..4 {
        let q = format!("p{i}a p{i}b p{i}c p{i}d p{i}e p{i}f");
        let r = handle.request(&q).unwrap();
        assert_eq!(r.pathway, Pathway::Miss, "prime {q} must miss");
    }
    // Phase 2: concurrent mix — paraphrases (5/6 words shared with their
    // prime -> tweak-hit) interleaved with fresh disjoint misses.
    let mut queries = Vec::new();
    for i in 0..4 {
        queries.push(format!("p{i}a p{i}b p{i}c p{i}d p{i}e p{i}g"));
        queries.push(format!("m{i}a m{i}b m{i}c m{i}d m{i}e m{i}f"));
    }
    let mut joins = Vec::new();
    for (t, chunk) in queries.chunks(2).enumerate() {
        let h = handle.clone();
        let chunk: Vec<String> = chunk.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for q in chunk {
                let r = h.request(&q).unwrap();
                out.push((q, r.pathway, r.text));
            }
            (t, out)
        }));
    }
    let mut results = Vec::new();
    for j in joins {
        let (_, out) = j.join().unwrap();
        for (q, pathway, text) in out {
            if q.starts_with('p') {
                assert_eq!(pathway, Pathway::TweakHit, "paraphrase {q} must tweak");
            } else {
                assert_eq!(pathway, Pathway::Miss, "fresh {q} must miss");
            }
            results.push((q, text));
        }
    }
    engine.shutdown();
    results.sort();
    results
}

/// N concurrent sessions must produce responses bit-identical to sequential
/// runs: the per-session RNG contract, gated end-to-end through the engine.
#[test]
fn scheduler_streams_match_sequential() {
    let interleaved = run_workload(true, 0);
    let sequential = run_workload(false, 0);
    assert_eq!(interleaved, sequential);
}

/// The batched-decode identity gate: a mixed tweak/miss workload served
/// through collective slot pools (including overflow past the 3 slots) must
/// produce responses bit-identical to the per-session path.
#[test]
fn batched_decode_streams_match_per_session() {
    let batched = run_workload(true, 3);
    let per_session = run_workload(true, 0);
    assert_eq!(batched, per_session);
}

/// The KV-prefix-cache identity gate through the engine: a mixed workload of
/// concurrent tweak-hits and fresh misses must produce responses bitwise
/// identical with prefix reuse on vs off, while the reuse-on run counts
/// hits/misses/saved-tokens in `EngineStats`. The mock's reuse simulation
/// shares the real cache's keying (literal token prefixes at chunk depths
/// over the suffixed tweak encoding), so a text divergence here means the
/// prompt layout leaked the suffix into the prefix key.
#[test]
fn prefix_reuse_identity_and_stats_through_engine() {
    let run = |reuse: bool| {
        let cfg = base_config();
        let small = if reuse {
            MockLlm::new("small").with_prefix_reuse(&[32], 16, Duration::from_micros(100))
        } else {
            MockLlm::new("small")
        };
        let (engine, handle) = start_engine(cfg, MockLlm::new("big"), small);
        // Primes: two disjoint cache entries for the tweak path to target.
        for i in 0..2 {
            let q = format!("c{i}a c{i}b c{i}c c{i}d c{i}e c{i}f");
            assert_eq!(handle.request(&q).unwrap().pathway, Pathway::Miss, "prime {q}");
        }
        // Concurrent mix: paraphrases of both primes (5/6 words shared ->
        // tweak-hit, all sharing the prime's cached pair and hence its
        // prefix key) interleaved with fresh disjoint misses.
        let mut queries = Vec::new();
        for t in 0..3 {
            for i in 0..2 {
                queries.push(format!("c{i}a c{i}b c{i}c c{i}d c{i}e x{t}{i}"));
            }
            queries.push(format!("m{t}a m{t}b m{t}c m{t}d m{t}e m{t}f"));
        }
        let mut joins = Vec::new();
        for chunk in queries.chunks(3) {
            let h = handle.clone();
            let chunk: Vec<String> = chunk.to_vec();
            joins.push(std::thread::spawn(move || {
                chunk
                    .into_iter()
                    .map(|q| {
                        let r = h.request(&q).unwrap();
                        (q, r.pathway, r.text)
                    })
                    .collect::<Vec<_>>()
            }));
        }
        let mut results = Vec::new();
        for j in joins {
            for (q, pathway, text) in j.join().unwrap() {
                if q.starts_with('c') {
                    assert_eq!(pathway, Pathway::TweakHit, "paraphrase {q} must tweak");
                } else {
                    assert_eq!(pathway, Pathway::Miss, "fresh {q} must miss");
                }
                results.push((q, text));
            }
        }
        // A final sequential tweak: by now the prefix is guaranteed seeded,
        // so with reuse on this one must restore rather than recompute.
        let last = handle.request("c0a c0b c0c c0d c0e zfin").unwrap();
        assert_eq!(last.pathway, Pathway::TweakHit);
        results.push(("c0a c0b c0c c0d c0e zfin".to_string(), last.text));
        let stats = handle.stats().unwrap();
        engine.shutdown();
        results.sort();
        (results, stats)
    };
    let (on, on_stats) = run(true);
    let (off, off_stats) = run(false);
    assert_eq!(on, off, "prefix reuse must not change a single response byte");
    // 7 tweaks over 2 distinct cached pairs: the first probe per pair seeds
    // (a miss), every later one restores the 32-token prefix — regardless of
    // the order the concurrent threads arrive in.
    assert_eq!(on_stats.prefix_hits, 5, "hits: {on_stats:?}");
    assert_eq!(on_stats.prefix_misses, 2, "misses: {on_stats:?}");
    assert_eq!(on_stats.prefix_saved_tokens, 5 * 32);
    assert_eq!(on_stats.prefix_evictions, 0);
    assert_eq!(
        off_stats.prefix_hits + off_stats.prefix_misses,
        0,
        "reuse off must never touch a prefix cache"
    );
}

/// Engine-level occupancy observability: concurrent batched sessions must
/// show up as few dispatches with multi-slot occupancy in `EngineStats`.
#[test]
fn engine_stats_report_batch_occupancy() {
    let cfg = base_config();
    let big = MockLlm::new("big")
        .with_pace(10, Duration::from_millis(3))
        .with_batch(4);
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(4));
    let (done_tx, done_rx) = mpsc::channel();
    for i in 0..4 {
        let h = handle.clone();
        let done = done_tx.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        std::thread::spawn(move || {
            barrier.wait();
            let _ = done.send(h.request(&format!("occ{i}a occ{i}b occ{i}c occ{i}d")));
        });
    }
    for _ in 0..4 {
        let r = done_rx.recv_timeout(Duration::from_secs(20)).unwrap().unwrap();
        assert_eq!(r.pathway, Pathway::Miss);
    }
    let stats = handle.stats().unwrap();
    // 4 sessions × 10 steps through per-session dispatch would be 40; the
    // pool must have shared rounds (some stagger between arrivals is fine).
    assert!(stats.batched_steps >= 10, "stats: {}", stats.batched_steps);
    assert!(
        stats.batched_steps <= 20,
        "dispatches must be shared across sessions, got {}",
        stats.batched_steps
    );
    assert!(
        stats.mean_active_slots >= 2.0,
        "mean occupancy must reflect concurrent slots, got {}",
        stats.mean_active_slots
    );
}
