//! Decode-scheduler integration tests over the real engine + batcher (no
//! artifacts needed): the two PR-4 bug regressions (batch-leftover
//! starvation, duplicate-in-batch double generation), head-of-line
//! unblocking, and the scheduler-on == scheduler-off response-identity
//! gate.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tweakllm::baselines::MockLlm;
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Pathway, Router};
use tweakllm::cost::TokenUsage;
use tweakllm::llm::{LanguageModel, LlmResponse, LlmSession, TweakPrompt};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::util::Rng;

fn base_config() -> Config {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg
}

fn start_engine(cfg: Config, big: MockLlm, small: MockLlm) -> (Engine, EngineHandle) {
    Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg))
    })
    .expect("engine start")
}

/// Regression (batch-leftover starvation): a burst larger than `max_batch`
/// followed by silence must complete in full. The old serve loop flushed at
/// most `max_batch` drained requests and then parked on a blocking `recv`,
/// stranding any leftovers in the batcher forever. Both engine modes are
/// gated — the run-to-completion (scheduler-off) path had the same bug.
fn burst_completes(scheduler_on: bool) {
    let mut cfg = base_config();
    cfg.batcher.max_batch = 2;
    cfg.scheduler.enabled = scheduler_on;
    // A slow Big LLM keeps the engine busy so the burst piles up in the
    // channel and gets ingested into the batcher well past max_batch.
    let big = MockLlm::new("big").with_pace(5, Duration::from_millis(2));
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    let n = 7;
    let (done_tx, done_rx) = mpsc::channel();
    for i in 0..n {
        let h = handle.clone();
        let done = done_tx.clone();
        std::thread::spawn(move || {
            let r = h.request(&format!("burst{i}a burst{i}b burst{i}c burst{i}d"));
            let _ = done.send((i, r));
        });
    }
    drop(done_tx);
    let mut served = 0;
    for _ in 0..n {
        let (i, r) = done_rx
            .recv_timeout(Duration::from_secs(20))
            .unwrap_or_else(|_| panic!("request stranded after {served}/{n} replies"));
        let resp = r.unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(resp.pathway, Pathway::Miss);
        served += 1;
    }
    assert_eq!(served, n);
}

#[test]
fn burst_larger_than_max_batch_completes() {
    burst_completes(true);
}

#[test]
fn burst_larger_than_max_batch_completes_scheduler_off() {
    burst_completes(false);
}

/// Regression (duplicate queries inside one micro-batch): two identical
/// missed queries must pay ONE Big-LLM generation and insert ONE cache row.
/// The old flush ran the exact-match check once for the whole batch before
/// any routing, so both paid a generation and the first insert became an
/// unreachable stale row.
#[test]
fn duplicate_in_batch_pays_one_generation() {
    let cfg = base_config();
    // Slow misses (~120ms): the duplicate pair is guaranteed to be routed
    // while the leader's generation is still in flight.
    let big = MockLlm::new("big").with_pace(60, Duration::from_millis(2));
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    let barrier = std::sync::Arc::new(std::sync::Barrier::new(2));
    let (done_tx, done_rx) = mpsc::channel();
    for _ in 0..2 {
        let h = handle.clone();
        let done = done_tx.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        std::thread::spawn(move || {
            barrier.wait();
            // Same normalized text (whitespace + case fold) on both.
            let _ = done.send(h.request("what is a  B-TREE exactly"));
        });
    }
    let a = done_rx.recv_timeout(Duration::from_secs(20)).unwrap();
    let b = done_rx.recv_timeout(Duration::from_secs(20)).unwrap();
    let (a, b) = (a.unwrap(), b.unwrap());
    assert_eq!(a.text, b.text, "duplicates must share one generation");
    assert_eq!(a.cache_entry, b.cache_entry);

    let stats = handle.stats().unwrap();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.misses, 1, "exactly one Big-LLM generation");
    assert_eq!(stats.exact_hits, 1, "the duplicate reports as an exact hit");
    assert_eq!(stats.cache_size, 1, "no duplicate cache row");
    assert_eq!(stats.coalesced, 1, "second dup coalesced onto the in-flight miss");
}

/// The tentpole behavior: a tweak-hit completes while a slow Big-LLM miss
/// is still decoding (no head-of-line blocking).
#[test]
fn tweak_hit_overtakes_inflight_miss() {
    let cfg = base_config();
    let big = MockLlm::new("big").with_pace(40, Duration::from_millis(2));
    let (_engine, handle) = start_engine(cfg, big, MockLlm::new("small"));

    // Prime an entry for the tweak path (pays one slow generation).
    let prime = handle.request("why is coffee good for health?").unwrap();
    assert_eq!(prime.pathway, Pathway::Miss);

    // Start a slow miss, then a tweak-hit 15ms behind it.
    let h = handle.clone();
    let miss = std::thread::spawn(move || {
        let r = h.request("write a poem about glaciers").unwrap();
        (r, Instant::now())
    });
    std::thread::sleep(Duration::from_millis(15));
    let tweak = handle.request("why is coffee great for health?").unwrap();
    let tweak_done = Instant::now();
    let (miss_resp, miss_done) = miss.join().unwrap();

    assert_eq!(tweak.pathway, Pathway::TweakHit);
    assert_eq!(miss_resp.pathway, Pathway::Miss);
    assert!(tweak_done < miss_done, "tweak-hit must overtake the in-flight miss");
}

// ---------------------------------------------------------------------------
// Response-identity gate: scheduler-interleaved == sequential, bit for bit.
// ---------------------------------------------------------------------------

/// A mock whose output is drawn from a per-session RNG substream keyed on
/// the full prompt — the same contract `SubstrateLlm` honors. If sessions
/// leaked RNG state across each other, the concurrent (interleaved) run
/// below would diverge from the sequential one.
struct SeededLlm {
    name: String,
    seed: u64,
    steps: usize,
}

struct SeededSession {
    rng: Rng,
    prefix: String,
    steps: usize,
    emitted: Vec<String>,
}

impl LlmSession for SeededSession {
    fn advance(&mut self) -> Result<bool> {
        if self.emitted.len() < self.steps {
            self.emitted.push(format!("t{}", self.rng.range(0, 10_000)));
        }
        Ok(self.emitted.len() < self.steps)
    }

    fn is_done(&self) -> bool {
        self.emitted.len() >= self.steps
    }

    fn finish(self: Box<Self>) -> Result<LlmResponse> {
        Ok(LlmResponse {
            text: format!("[{}] {}", self.prefix, self.emitted.join(" ")),
            usage: TokenUsage { input_tokens: 1, output_tokens: self.steps },
            prefill_micros: 0,
            decode_micros: 0,
        })
    }
}

impl SeededLlm {
    fn begin(&self, segments: &[&str]) -> Box<dyn LlmSession> {
        let tag = format!("{}/{}", self.name, segments.join("\u{1f}"));
        Box::new(SeededSession {
            rng: Rng::substream(self.seed, &tag),
            prefix: segments[0].to_string(),
            steps: self.steps,
            emitted: Vec::new(),
        })
    }
}

impl LanguageModel for SeededLlm {
    fn name(&self) -> &str {
        &self.name
    }

    fn respond(&mut self, query: &str) -> Result<LlmResponse> {
        let mut s = self.begin(&[query]);
        while s.advance()? {}
        s.finish()
    }

    fn tweak(&mut self, prompt: &TweakPrompt) -> Result<LlmResponse> {
        let segs = prompt.segments();
        let mut s = self.begin(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        while s.advance()? {}
        s.finish()
    }

    fn begin_respond(&mut self, query: &str) -> Result<Box<dyn LlmSession>> {
        Ok(self.begin(&[query]))
    }

    fn begin_tweak(&mut self, prompt: &TweakPrompt) -> Result<Box<dyn LlmSession>> {
        let segs = prompt.segments();
        Ok(self.begin(&segs.iter().map(|s| s.as_str()).collect::<Vec<_>>()))
    }
}

/// Run the two-phase workload (sequential primes, then a concurrent mix of
/// tweak-hit paraphrases and fresh misses) and collect query -> (pathway,
/// text).
fn run_workload(scheduler_on: bool) -> Vec<(String, String)> {
    let mut cfg = base_config();
    cfg.scheduler.enabled = scheduler_on;
    cfg.exact_match_fast_path = false; // repeats must exercise the tweak path
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(
            embedder,
            Box::new(SeededLlm { name: "big".into(), seed: 11, steps: 12 }),
            Box::new(SeededLlm { name: "small".into(), seed: 13, steps: 3 }),
            cfg,
        ))
    })
    .expect("engine start");

    // Phase 1: sequential primes — identical cache in both runs. Topic
    // word-sets are mutually disjoint so primes never tweak each other.
    for i in 0..4 {
        let q = format!("p{i}a p{i}b p{i}c p{i}d p{i}e p{i}f");
        let r = handle.request(&q).unwrap();
        assert_eq!(r.pathway, Pathway::Miss, "prime {q} must miss");
    }
    // Phase 2: concurrent mix — paraphrases (5/6 words shared with their
    // prime -> tweak-hit) interleaved with fresh disjoint misses.
    let mut queries = Vec::new();
    for i in 0..4 {
        queries.push(format!("p{i}a p{i}b p{i}c p{i}d p{i}e p{i}g"));
        queries.push(format!("m{i}a m{i}b m{i}c m{i}d m{i}e m{i}f"));
    }
    let mut joins = Vec::new();
    for (t, chunk) in queries.chunks(2).enumerate() {
        let h = handle.clone();
        let chunk: Vec<String> = chunk.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for q in chunk {
                let r = h.request(&q).unwrap();
                out.push((q, r.pathway, r.text));
            }
            (t, out)
        }));
    }
    let mut results = Vec::new();
    for j in joins {
        let (_, out) = j.join().unwrap();
        for (q, pathway, text) in out {
            if q.starts_with('p') {
                assert_eq!(pathway, Pathway::TweakHit, "paraphrase {q} must tweak");
            } else {
                assert_eq!(pathway, Pathway::Miss, "fresh {q} must miss");
            }
            results.push((q, text));
        }
    }
    engine.shutdown();
    results.sort();
    results
}

/// N concurrent sessions must produce responses bit-identical to sequential
/// runs: the per-session RNG contract, gated end-to-end through the engine.
#[test]
fn scheduler_streams_match_sequential() {
    let interleaved = run_workload(true);
    let sequential = run_workload(false);
    assert_eq!(interleaved, sequential);
}
