//! Integration gates for the sharded/segmented vector search subsystem:
//! SQ8 recall vs the exact scan, shard-count invariance, and stable-id
//! consistency across tombstone compaction and persistence round-trips.

use std::path::PathBuf;
use std::sync::Arc;

use tweakllm::cache::{
    EvictionPolicy, FlatIndex, IndexKind, IndexOpts, IvfFlatIndex, PersistConfig, Quantization,
    SemanticCache, VectorIndex,
};
use tweakllm::util::{normalize, Rng, ThreadPool};

fn rand_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    normalize(&mut v);
    v
}

/// Clustered data (the regime the paper's cache lives in: many near-
/// duplicate queries around popular intents).
fn clustered(rng: &mut Rng, n: usize, dim: usize, clusters: usize) -> Vec<Vec<f32>> {
    let centers: Vec<Vec<f32>> = (0..clusters).map(|_| rand_unit(rng, dim)).collect();
    (0..n)
        .map(|i| {
            let mut v: Vec<f32> = centers[i % clusters]
                .iter()
                .map(|x| x + 0.25 * rng.normal() as f32)
                .collect();
            normalize(&mut v);
            v
        })
        .collect()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tweakllm-index-{}-{}", std::process::id(), tag));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// SQ8 with exact re-rank must agree with the exact f32 scan on ≥ 95% of
/// top-1 answers over clustered data (the ISSUE acceptance bar).
#[test]
fn sq8_recall_at_1_vs_exact() {
    let dim = 96;
    let mut rng = Rng::new(11);
    let vs = clustered(&mut rng, 4000, dim, 16);
    let mut exact = FlatIndex::new(dim);
    let sq8_opts = IndexOpts {
        quantization: Quantization::Sq8,
        segment_rows: 512,
        ..IndexOpts::default()
    };
    let mut sq8 = FlatIndex::with_opts(dim, sq8_opts);
    for v in &vs {
        exact.insert(v);
        sq8.insert(v);
    }
    assert!(sq8.quant_params().is_some(), "SQ8 must train after the first seal");
    // Held-out queries: fresh perturbations of stored points.
    let mut agree = 0;
    let n_q = 300;
    for i in 0..n_q {
        let base = &vs[(i * 7) % vs.len()];
        let mut q: Vec<f32> =
            base.iter().map(|x| x + 0.05 * rng.normal() as f32).collect();
        normalize(&mut q);
        let a = exact.search(&q, 1)[0];
        let b = sq8.search(&q, 1)[0];
        if a.id == b.id {
            agree += 1;
        }
    }
    let recall = agree as f64 / n_q as f64;
    assert!(recall >= 0.95, "SQ8 recall@1 = {recall:.3} ({agree}/{n_q})");
}

/// 1 shard and N shards must return byte-identical results (same ids, same
/// scores, same order) for both index families and both storage modes.
#[test]
fn shard_count_invariance() {
    let dim = 48;
    let mut rng = Rng::new(12);
    let vs = clustered(&mut rng, 1200, dim, 8);
    let queries: Vec<Vec<f32>> = (0..32).map(|_| rand_unit(&mut rng, dim)).collect();
    for quant in [Quantization::None, Quantization::Sq8] {
        let opts = IndexOpts { quantization: quant, segment_rows: 128, ..IndexOpts::default() };
        // FLAT
        let mut base = FlatIndex::with_opts(dim, opts);
        let mut sharded = FlatIndex::with_opts(dim, opts);
        sharded.set_pool(Arc::new(ThreadPool::new(4)), 4);
        // IVF (trained: 1200 > train_after for nlist=4)
        let mut ivf_base = IvfFlatIndex::with_opts(dim, 4, 2, opts);
        let mut ivf_sharded = IvfFlatIndex::with_opts(dim, 4, 2, opts);
        ivf_sharded.set_pool(Arc::new(ThreadPool::new(4)), 4);
        for v in &vs {
            base.insert(v);
            sharded.insert(v);
            ivf_base.insert(v);
            ivf_sharded.insert(v);
        }
        for id in (0..vs.len()).step_by(9) {
            base.remove(id);
            sharded.remove(id);
            ivf_base.remove(id);
            ivf_sharded.remove(id);
        }
        for q in &queries {
            assert_eq!(base.search(q, 10), sharded.search(q, 10), "FLAT {quant:?}");
            assert_eq!(
                ivf_base.search(q, 10),
                ivf_sharded.search(q, 10),
                "IVF {quant:?}"
            );
        }
    }
}

/// Compaction rewrites segments but ids are stable: entries stay reachable
/// by the id `insert` returned, before and after compaction and after a
/// persist round-trip (quantized mode — params must round-trip too).
#[test]
fn compaction_and_persist_keep_stable_ids() {
    let dim = 32;
    let dir = tmp_dir("compact-persist");
    let pcfg = PersistConfig {
        data_dir: dir.to_string_lossy().to_string(),
        wal_fsync: false,
        compact_bytes: u64::MAX,
        fsync_batch_ms: 0,
    };
    let opts = IndexOpts {
        quantization: Quantization::Sq8,
        segment_rows: 64,
        compact_tombstone_frac: 0.2,
    };
    let mut rng = Rng::new(13);
    let vs = clustered(&mut rng, 600, dim, 6);
    let probes: Vec<Vec<f32>> = (0..16).map(|_| rand_unit(&mut rng, dim)).collect();
    let before_hits: Vec<_>;
    let survivors: Vec<usize>;
    {
        let (mut c, _) = SemanticCache::open_persistent_with(
            dim,
            IndexKind::Flat,
            opts,
            EvictionPolicy::None,
            usize::MAX,
            false,
            &pcfg,
        )
        .unwrap();
        let ids: Vec<usize> = vs
            .iter()
            .enumerate()
            .map(|(i, v)| c.insert(&format!("q{i}"), &format!("r{i}"), v.clone()))
            .collect();
        assert_eq!(ids, (0..vs.len()).collect::<Vec<_>>());
        // Persist (the snapshot carries the trained SQ8 params) and record
        // the pre-restart answers.
        before_hits = probes.iter().map(|q| c.search(q, 3)).collect();
        c.compact_now().unwrap();
        survivors = ids;
    }
    // Restart: identical hits (ids and scores) in quantized mode.
    let (mut c, report) = SemanticCache::open_persistent_with(
        dim,
        IndexKind::Flat,
        opts,
        EvictionPolicy::None,
        usize::MAX,
        false,
        &pcfg,
    )
    .unwrap();
    assert_eq!(report.recovered_entries as usize, survivors.len());
    for (q, want) in probes.iter().zip(&before_hits) {
        assert_eq!(&c.search(q, 3), want, "post-restart hits differ");
    }
    for &id in survivors.iter().step_by(17) {
        assert_eq!(c.entry(id).unwrap().response_text, format!("r{id}"));
    }
    let _ = std::fs::remove_dir_all(&dir);

    // The index-level twin: explicit removals trigger compaction; stable
    // ids survive segment rewrites.
    let mut idx = FlatIndex::with_opts(dim, opts);
    for v in &vs {
        idx.insert(v);
    }
    let removed: Vec<usize> = (0..vs.len()).step_by(3).collect();
    for &id in &removed {
        idx.remove(id);
    }
    assert_eq!(idx.live_len(), vs.len() - removed.len());
    for (id, v) in vs.iter().enumerate() {
        if removed.contains(&id) {
            assert!(idx.search(v, 5).iter().all(|h| h.id != id), "tombstone {id} matched");
        } else {
            assert_eq!(idx.search(v, 1)[0].id, id, "stable id {id} lost in compaction");
        }
    }
}

/// Eviction-heavy persistent cache in quantized mode: tombstones round-trip
/// and survivors keep their ids (the store-level id-stability gate).
#[test]
fn quantized_eviction_roundtrip() {
    let dim = 24;
    let dir = tmp_dir("sq8-evict");
    let pcfg = PersistConfig {
        data_dir: dir.to_string_lossy().to_string(),
        wal_fsync: false,
        compact_bytes: u64::MAX,
        fsync_batch_ms: 0,
    };
    let opts = IndexOpts {
        quantization: Quantization::Sq8,
        segment_rows: 32,
        compact_tombstone_frac: 0.25,
    };
    let mut rng = Rng::new(14);
    let vs: Vec<Vec<f32>> = (0..120).map(|_| rand_unit(&mut rng, dim)).collect();
    let cap = 80;
    {
        let (mut c, _) = SemanticCache::open_persistent_with(
            dim,
            IndexKind::Flat,
            opts,
            EvictionPolicy::Fifo,
            cap,
            false,
            &pcfg,
        )
        .unwrap();
        for (i, v) in vs.iter().enumerate() {
            c.insert(&format!("q{i}"), &format!("r{i}"), v.clone());
        }
        assert_eq!(c.len(), cap);
        assert_eq!(c.stats().evictions as usize, vs.len() - cap);
        c.compact_now().unwrap();
    }
    let (mut c, _) = SemanticCache::open_persistent_with(
        dim,
        IndexKind::Flat,
        opts,
        EvictionPolicy::Fifo,
        cap,
        false,
        &pcfg,
    )
    .unwrap();
    assert_eq!(c.len(), cap);
    // FIFO evicted the oldest 40; survivors answer by their original ids.
    for dead in 0..(vs.len() - cap) {
        assert!(c.entry(dead).is_none());
        let hits = c.search(&vs[dead], 5);
        assert!(hits.iter().all(|h| h.id != dead), "evicted id {dead} matched");
    }
    for live in (vs.len() - cap)..vs.len() {
        assert_eq!(c.search(&vs[live], 1)[0].id, live, "id {live} lost");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
