//! Runtime integration tests over the REAL compiled artifacts (skipped with
//! a notice when `artifacts/` hasn't been built — run `make artifacts`).
//!
//! These validate the full AOT bridge: HLO text → PJRT compile → execute,
//! numerics (unit-norm embeddings, paraphrase structure), the
//! prefill/decode KV-cache contract, and the artifact-backed router.

use tweakllm::config::Config;
use tweakllm::coordinator::{Pathway, Router};
use tweakllm::runtime::{Embedder, Generator, Runtime, SamplingParams, TextEmbedder};
use tweakllm::util::{dot, Rng};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TWEAKLLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn embedder_unit_norm_and_determinism() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["embed_b1", "embed_b8", "embed_b32"]).unwrap();
    let e = Embedder::new(&rt).unwrap();
    let a = e.embed("why is coffee good for health?").unwrap();
    let b = e.embed("why is coffee good for health?").unwrap();
    assert_eq!(a, b, "embedding must be deterministic");
    let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-4, "norm={norm}");
    assert_eq!(a.len(), 384);
}

#[test]
fn embedder_batch_variants_agree() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["embed_b1", "embed_b8", "embed_b32"]).unwrap();
    let e = Embedder::new(&rt).unwrap();
    let texts: Vec<String> = (0..5)
        .map(|i| format!("question number {i} about topic {i}"))
        .collect();
    let views: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    // batch of 5 routes through the b8 variant; singles through b1
    let batched = e.embed_batch(&views).unwrap();
    for (i, t) in texts.iter().enumerate() {
        let single = e.embed(t).unwrap();
        let cos = dot(&single, &batched[i]);
        assert!(cos > 0.9999, "b1 vs b8 disagree: cos={cos}");
    }
}

#[test]
fn embedder_semantic_structure() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["embed_b1", "embed_b8", "embed_b32"]).unwrap();
    let e = Embedder::new(&rt).unwrap();
    let base = e.embed("why is coffee good for health?").unwrap();
    let para = e.embed("why is coffee great for health?").unwrap();
    let flip = e.embed("why is coffee bad for health?").unwrap();
    let unrel = e.embed("draft an email to my landlord about rent").unwrap();
    assert!(dot(&base, &para) > dot(&base, &unrel) + 0.15);
    // the paper's false-positive regime: polarity flips stay in the
    // cacheable zone (>= the 0.7 routing threshold)
    assert!(dot(&base, &flip) > 0.7, "flip cos = {}", dot(&base, &flip));
}

#[test]
fn generator_deterministic_and_bounded() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["small_prefill", "small_decode"]).unwrap();
    let g = Generator::new(&rt, "small").unwrap();
    let params = SamplingParams { temperature: 1.0, top_k: 40, max_new_tokens: 8 };
    let gen1 = g.generate(&["tell me about rust"], &params, &mut Rng::new(5)).unwrap();
    let gen2 = g.generate(&["tell me about rust"], &params, &mut Rng::new(5)).unwrap();
    assert_eq!(gen1.token_ids, gen2.token_ids, "same seed => same tokens");
    assert!(gen1.token_ids.len() <= 8);
    assert!(gen1.stats.prompt_tokens > 0);
    let gen3 = g.generate(&["tell me about rust"], &params, &mut Rng::new(6)).unwrap();
    // different seed should (almost surely) sample a different path
    assert_ne!(gen1.token_ids, gen3.token_ids);
}

#[test]
fn generator_greedy_is_sampling_free() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["small_prefill", "small_decode"]).unwrap();
    let g = Generator::new(&rt, "small").unwrap();
    let params = SamplingParams::greedy(6);
    let a = g.generate(&["greedy check"], &params, &mut Rng::new(1)).unwrap();
    let b = g.generate(&["greedy check"], &params, &mut Rng::new(999)).unwrap();
    assert_eq!(a.token_ids, b.token_ids, "greedy must ignore the rng");
}

#[test]
fn device_resident_matches_literal_token_stream() {
    // The device-resident transport is a pure transport optimization: for
    // greedy AND seeded top-k sampling it must emit bit-identical token
    // streams (and stats) to the literal path, through the span, tail, and
    // single-step phases alike.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &[]).unwrap();
    let g = Generator::new(&rt, "small").unwrap();
    if !g.resident_available() {
        eprintln!("SKIP: artifact set predates device-resident decode");
        return;
    }
    let cases = [
        SamplingParams::greedy(40),
        SamplingParams { temperature: 1.0, top_k: 40, max_new_tokens: 40 },
        // non-span-eligible params exercise the pure single-step path
        SamplingParams { temperature: 0.9, top_k: 7, max_new_tokens: 12 },
    ];
    for params in cases {
        let lit = g
            .generate_on(&["compare the decode transports"], &params, &mut Rng::new(11), false)
            .unwrap();
        let res = g
            .generate_on(&["compare the decode transports"], &params, &mut Rng::new(11), true)
            .unwrap();
        assert_eq!(
            lit.token_ids, res.token_ids,
            "transports diverged at temp={} top_k={}",
            params.temperature, params.top_k
        );
        assert_eq!(lit.stats.generated_tokens, res.stats.generated_tokens);
        assert_eq!(lit.text, res.text);
        assert!(!lit.stats.device_resident);
        assert!(res.stats.device_resident);
    }
}

#[test]
fn device_resident_determinism_and_repeatability() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &[]).unwrap();
    let g = Generator::new(&rt, "small").unwrap();
    if !g.resident_available() {
        eprintln!("SKIP: artifact set predates device-resident decode");
        return;
    }
    let params = SamplingParams { temperature: 1.0, top_k: 40, max_new_tokens: 24 };
    let a = g.generate(&["tell me about rust"], &params, &mut Rng::new(5)).unwrap();
    let b = g.generate(&["tell me about rust"], &params, &mut Rng::new(5)).unwrap();
    assert_eq!(a.token_ids, b.token_ids, "resident decode must be deterministic");
    assert!(a.stats.device_resident, "resident artifacts present but not used");
}

#[test]
fn batched_decode_matches_per_session_streams() {
    // The tentpole identity gate on the real substrate: S sessions advanced
    // through the slot-batched pool (one masked dispatch per round) must
    // emit bit-identical token streams to independent per-session resident
    // decodes with the same per-request RNG substreams. Span-ineligible
    // sampling params keep the per-session reference on the single-step
    // path the batched pool always takes.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &[]).unwrap();
    let g = Generator::new(&rt, "small").unwrap();
    if g.batch_sizes().is_empty() {
        eprintln!("SKIP: artifact set predates batched decode");
        return;
    }
    let params = SamplingParams { temperature: 0.9, top_k: 7, max_new_tokens: 12 };
    let prompts = [
        "compare the decode transports",
        "tell me about rust",
        "why is coffee good for health",
    ];
    let refs: Vec<Vec<i32>> = prompts
        .iter()
        .map(|&p| {
            let mut s = g
                .begin_session_on(&[p], &params, Rng::substream(3, p), g.resident_available())
                .unwrap();
            while s.advance().unwrap() {}
            s.finish().token_ids
        })
        .collect();
    let mut pool = g.begin_batch(8).expect("batched artifacts discovered");
    let slots: Vec<usize> = prompts
        .iter()
        .map(|&p| {
            let (ids, len) = g.tokenizer().encode_prompt(&[p], g.max_prefill());
            pool.admit(&ids, len, params, Rng::substream(3, p))
                .unwrap()
                .expect("free slot")
        })
        .collect();
    // round-robin like the scheduler: one advance per live slot per sweep
    while slots.iter().any(|&s| !pool.is_done(s)) {
        for &s in &slots {
            if !pool.is_done(s) {
                pool.advance(s).unwrap();
            }
        }
    }
    assert!(pool.dispatches() > 0);
    let longest = refs.iter().map(|r| r.len()).max().unwrap() as u64;
    assert!(
        pool.dispatches() <= longest,
        "O(1) dispatches per round: {} dispatches for longest stream {}",
        pool.dispatches(),
        longest
    );
    for (i, &s) in slots.iter().enumerate() {
        let (toks, stats) = pool.finish(s).unwrap();
        assert_eq!(toks, refs[i], "slot {i} diverged from its per-session stream");
        assert!(stats.device_resident);
    }
}

#[test]
fn batched_decode_fallback_when_artifacts_absent() {
    // Load ONLY per-session artifacts: bucket discovery must come up empty
    // and the LLM layer must keep serving through per-session dispatch.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["small_prefill", "small_decode"]).unwrap();
    let g = Generator::new(&rt, "small").unwrap();
    assert!(g.batch_sizes().is_empty());
    assert!(g.begin_batch(8).is_none());
    let mut llm = tweakllm::llm::SubstrateLlm::new(
        &rt,
        "small",
        SamplingParams { temperature: 0.9, top_k: 7, max_new_tokens: 8 },
        7,
    )
    .unwrap()
    .with_decode_batch(8);
    assert!(!llm.batched(), "no batched artifacts → per-session fallback");
    use tweakllm::llm::LanguageModel;
    let r = llm.respond("fallback still serves").unwrap();
    assert!(r.usage.output_tokens > 0);
}

#[test]
fn prefix_resumed_prefill_matches_cold() {
    // The KV-prefix-cache identity gate on the real substrate: the first
    // tweak against a cached pair runs cold and snapshots its prefix state;
    // a second tweak with a different new-query suffix must restore that
    // snapshot and still emit a bit-identical response to a cache-less run.
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &[]).unwrap();
    {
        let g = Generator::new(&rt, "small").unwrap();
        if !g.resident_available() || g.resume_chunks().is_empty() {
            eprintln!("SKIP: artifact set predates prefill resume");
            return;
        }
    }
    use tweakllm::llm::{LanguageModel, SubstrateLlm, TweakPrompt};
    let params = SamplingParams { temperature: 0.9, top_k: 7, max_new_tokens: 8 };
    // A long cached response pushes the stable prefix past every resume
    // chunk depth, so the second tweak restores at the deepest one.
    let resp: String = (0..120).map(|i| format!("w{i}")).collect::<Vec<_>>().join(" ");
    let prompt = |q: &str| TweakPrompt {
        new_query: q.into(),
        cached_query: "why is coffee good for health?".into(),
        cached_response: resp.clone(),
    };
    let queries = ["why is coffee great for health?", "is coffee actually good for you"];
    let cold: Vec<_> = {
        let mut llm = SubstrateLlm::new(&rt, "small", params, 7).unwrap();
        queries.iter().map(|&q| llm.tweak(&prompt(q)).unwrap()).collect()
    };
    let mut llm =
        SubstrateLlm::new(&rt, "small", params, 7).unwrap().with_prefix_cache(64 << 20);
    let resumed: Vec<_> = queries.iter().map(|&q| llm.tweak(&prompt(q)).unwrap()).collect();
    for (i, (c, r)) in cold.iter().zip(&resumed).enumerate() {
        assert_eq!(c.text, r.text, "query {i}: resumed prefill diverged from cold");
        assert_eq!(c.usage.output_tokens, r.usage.output_tokens, "query {i}");
    }
    // The cache-less run never restores; the cached run must have resumed
    // on the second tweak (same prefix, different suffix).
    assert!(cold.iter().all(|c| c.restored_tokens == 0));
    assert!(
        resumed[1].restored_tokens > 0,
        "second tweak must report restored prefix tokens"
    );
    let stats = llm.prefix_stats().expect("prefix cache enabled");
    assert!(stats.hits >= 1, "stats: {stats:?}");
    assert!(stats.saved_tokens > 0, "stats: {stats:?}");
    assert!(stats.entries > 0 && stats.bytes > 0, "stats: {stats:?}");
}

#[test]
fn artifact_router_full_pipeline() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &[]).unwrap();
    let mut cfg = Config::test();
    cfg.artifact_dir = dir;
    cfg.exact_match_fast_path = true;
    let mut router = Router::from_runtime(&rt, cfg).unwrap();

    let miss = router.handle("why is green tea good for sleep?").unwrap();
    assert_eq!(miss.pathway, Pathway::Miss);
    assert!(miss.usage.output_tokens > 0);

    let hit = router.handle("why is green tea great for sleep?").unwrap();
    assert_eq!(hit.pathway, Pathway::TweakHit, "sim={:?}", hit.similarity);
    assert!(hit.usage.output_tokens > 0);

    let exact = router.handle("why is green tea good for sleep?").unwrap();
    assert_eq!(exact.pathway, Pathway::ExactHit);
    assert_eq!(exact.usage.output_tokens, 0);

    // hit pathway must be cheaper in tokens*price than miss pathway
    let c = &router.config.cost;
    assert!(router.ledger.dollars(c) < router.ledger.baseline_dollars(c));
}

#[test]
fn compiled_cosine_artifact_matches_native() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir, &["cosine_scores_b4096"]).unwrap();
    let exe = rt.executable("cosine_scores_b4096").unwrap();
    let mut rng = Rng::new(3);
    let dim = 384;
    let n = 4096;
    let mut db = vec![0.0f32; n * dim];
    for x in db.iter_mut() {
        *x = rng.normal() as f32;
    }
    // normalize rows
    for row in db.chunks_mut(dim) {
        tweakllm::util::normalize(row);
    }
    let q: Vec<f32> = db[7 * dim..8 * dim].to_vec();
    let outs = exe
        .run(&[
            tweakllm::runtime::HostTensor::f32(db.clone(), &[n, dim]),
            tweakllm::runtime::HostTensor::f32(q.clone(), &[dim]),
        ])
        .unwrap();
    let scores = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(scores.len(), n);
    // self-similarity at row 7
    assert!((scores[7] - 1.0).abs() < 1e-4, "scores[7]={}", scores[7]);
    // spot-check against native dot
    for i in [0usize, 100, 4095] {
        let native = dot(&db[i * dim..(i + 1) * dim], &q);
        assert!((native - scores[i]).abs() < 1e-4);
    }
}
