//! Span-trace assembly tests: every served request — all four pathways,
//! scheduler on and off — finishes exactly one well-formed span tree, and
//! the latency recorder sees exactly one "total" sample per request.

use std::sync::mpsc;
use std::time::Instant;

use tweakllm::baselines::MockLlm;
use tweakllm::cache::query_key;
use tweakllm::config::{Config, IndexKindConfig, SchedulerConfig};
use tweakllm::coordinator::{
    Engine, EngineHandle, Job, JobKind, Pathway, RouteDecision, RoutedResponse, Router, Scheduler,
};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::trace::{FinishedTrace, Stage, TraceTag};

/// Structural invariants every finished trace must satisfy: spans sorted by
/// start, every span inside [0, total_us], depth-1 spans disjoint (so their
/// durations sum to at most the total), round children nested in the decode
/// parent.
fn assert_well_formed(ft: &FinishedTrace) {
    assert!(!ft.spans.is_empty(), "{:?}: no spans", ft.tag);
    let mut prev_start = 0;
    let mut prev_depth1_end = 0;
    let mut depth1_sum = 0;
    for s in &ft.spans {
        assert!(s.start_us >= prev_start, "{:?}: spans not sorted", ft.tag);
        prev_start = s.start_us;
        assert!(s.end_us >= s.start_us);
        assert!(
            s.end_us <= ft.total_us,
            "{:?}: span {:?} [{}, {}] exceeds total {}",
            ft.tag,
            s.stage,
            s.start_us,
            s.end_us,
            ft.total_us
        );
        if s.stage.depth() == 1 {
            assert!(
                s.start_us >= prev_depth1_end,
                "{:?}: {:?} overlaps the previous stage",
                ft.tag,
                s.stage
            );
            prev_depth1_end = s.end_us;
            depth1_sum += s.end_us - s.start_us;
        }
    }
    assert!(
        depth1_sum <= ft.total_us,
        "{:?}: stage sum {} > total {}",
        ft.tag,
        depth1_sum,
        ft.total_us
    );
    if let Some(d) = ft.span(Stage::Decode) {
        for s in ft.spans.iter().filter(|s| s.stage == Stage::DecodeRound) {
            assert!(
                s.start_us >= d.start_us && s.end_us <= d.end_us,
                "{:?}: round span outside the decode parent",
                ft.tag
            );
        }
    }
}

fn start_engine(scheduler_on: bool) -> (Engine, EngineHandle) {
    Engine::start(move || {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        cfg.scheduler.enabled = scheduler_on;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(
            embedder,
            Box::new(MockLlm::new("big")),
            Box::new(MockLlm::new("small")),
            cfg,
        ))
    })
    .expect("engine start")
}

/// Miss, tweak-hit paraphrase, exact repeat — then pull the traces back
/// through the engine and check tags, scores, and tree shape.
fn engine_pathways_traced(scheduler_on: bool) {
    let (_engine, handle) = start_engine(scheduler_on);
    handle.request("why is coffee good for health?").unwrap(); // miss
    handle.request("why is coffee great for health?").unwrap(); // tweak
    handle.request("why is coffee good for health?").unwrap(); // exact

    let report = handle.traces(16).unwrap();
    assert_eq!(report.finished, 3);
    assert_eq!(report.dropped, 0);
    let tags: Vec<TraceTag> = report.traces.iter().map(|t| t.tag).collect();
    assert_eq!(
        tags,
        vec![TraceTag::ExactHit, TraceTag::TweakHit, TraceTag::Miss],
        "newest first"
    );
    for ft in &report.traces {
        assert_well_formed(ft);
        assert!(ft.span(Stage::Ingest).is_some(), "{:?}", ft.tag);
        assert!(ft.span(Stage::BatcherWait).is_some(), "{:?}", ft.tag);
        assert!(ft.span(Stage::Route).is_some(), "{:?}", ft.tag);
        assert!(ft.span(Stage::Reply).is_some(), "{:?}", ft.tag);
    }

    let exact = &report.traces[0];
    assert_eq!(exact.similarity, 1.0);
    assert_eq!(exact.span(Stage::Route).unwrap().value, 1.0);

    let tweak = &report.traces[1];
    assert!(tweak.similarity >= 0.7, "sim {}", tweak.similarity);
    let route = tweak.span(Stage::Route).unwrap();
    assert_eq!(route.value, tweak.similarity, "route span carries the score");
    for stage in [Stage::Embed, Stage::Search, Stage::Prefill, Stage::Decode] {
        assert!(tweak.span(stage).is_some(), "tweak missing {stage:?}");
    }

    let miss = &report.traces[2];
    for stage in [Stage::Embed, Stage::Search, Stage::Prefill, Stage::Decode, Stage::CacheInsert] {
        assert!(miss.span(stage).is_some(), "miss missing {stage:?}");
    }
    if scheduler_on {
        assert!(miss.span(Stage::QueueWait).is_some());
        assert!(miss.decode_rounds >= 1, "no fairness rounds recorded");
        assert!(miss.spans.iter().any(|s| s.stage == Stage::DecodeRound));
        // round spans carry the batch-slot occupancy of their round
        for s in miss.spans.iter().filter(|s| s.stage == Stage::DecodeRound) {
            assert!(s.value >= 1.0, "occupancy {}", s.value);
        }
    }
}

#[test]
fn engine_traces_all_pathways_scheduler_on() {
    engine_pathways_traced(true);
}

#[test]
fn engine_traces_all_pathways_scheduler_off() {
    engine_pathways_traced(false);
}

// ---- deterministic scheduler-level tests (no engine thread) ----

fn test_router(max_sessions: usize) -> Router {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg.scheduler = SchedulerConfig {
        enabled: true,
        max_concurrent_sessions: max_sessions,
        fairness_steps: 1,
        decode_batch: 0,
    };
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    Router::with_models(
        embedder,
        Box::new(MockLlm::new("big").with_pace(3, std::time::Duration::ZERO)),
        Box::new(MockLlm::new("small")),
        cfg,
    )
}

/// Mirror the engine's per-request path with a live trace: begin, embed,
/// route, submit (or resolve the exact hit in place).
fn submit_traced(
    sched: &mut Scheduler,
    router: &mut Router,
    query: &str,
) -> mpsc::Receiver<anyhow::Result<RoutedResponse>> {
    let (tx, rx) = mpsc::channel();
    let enqueued = Instant::now();
    let mut trace = router.traces.begin(query, enqueued);
    let t = Instant::now();
    let emb = router.embedder().embed(query).unwrap();
    trace.span_from(Stage::Embed, t);
    let kind = match router.route(query, emb, enqueued, &mut trace) {
        RouteDecision::Exact(resp) => {
            tx.send(Ok(resp)).unwrap();
            return rx;
        }
        RouteDecision::Tweak(t) => JobKind::Tweak(t),
        RouteDecision::Miss(m) => {
            let key = query_key(&m.query);
            JobKind::Miss { job: m, key }
        }
    };
    sched.submit(Job::traced(kind, tx, enqueued, trace), router);
    rx
}

#[test]
fn coalesced_follower_finishes_its_own_trace() {
    let mut router = test_router(4);
    let mut sched = Scheduler::new(router.config.scheduler);
    let q = "what is a quorum in raft consensus";
    let a = submit_traced(&mut sched, &mut router, q);
    let b = submit_traced(&mut sched, &mut router, q);
    assert_eq!(sched.coalesced(), 1, "duplicate must attach as follower");
    sched.drain(&mut router);
    let ra = a.recv().unwrap().unwrap();
    let rb = b.recv().unwrap().unwrap();
    assert_eq!(ra.pathway, Pathway::Miss);
    // Response-level pathway hides the coalescing (exact hit under the fast
    // path) — the trace tag tells the truth.
    assert_eq!(rb.pathway, Pathway::ExactHit);

    assert_eq!(router.traces.finished(), 2);
    let recent = router.traces.recent(2);
    // The leader's trace finishes inside complete_miss, the follower's in
    // the fan-out right after: newest first = [coalesced, miss].
    assert_eq!(recent[0].tag, TraceTag::Coalesced);
    assert_eq!(recent[1].tag, TraceTag::Miss);
    let follower = &recent[0];
    assert_well_formed(follower);
    assert!(
        follower.span(Stage::QueueWait).is_some(),
        "the leader's generation is the follower's queue wait"
    );
    assert!(follower.span(Stage::Reply).is_some());
    assert!(follower.span(Stage::Decode).is_none(), "followers run no session");
    let leader = &recent[1];
    assert_well_formed(leader);
    assert!(leader.span(Stage::CacheInsert).is_some());
    assert!(leader.decode_rounds >= 1);
}

#[test]
fn every_request_records_one_total_sample_and_one_trace() {
    // N mixed requests — miss, tweak, exact, coalesced duplicate, and
    // overflow past the 2-session cap — must yield exactly N "total"
    // latency samples, N finished traces, and a pathway partition that
    // sums to N. (Regression guard: double-recording on the scheduler
    // path, or dropping a follower's sample.)
    let mut router = test_router(2);
    let mut sched = Scheduler::new(router.config.scheduler);
    let mut rxs = Vec::new();
    rxs.push(submit_traced(&mut sched, &mut router, "inv0a inv0b inv0c inv0d inv0e inv0f"));
    sched.drain(&mut router); // prime lands in the cache before the repeats
    rxs.push(submit_traced(&mut sched, &mut router, "inv0a inv0b inv0c inv0d inv0e varyX"));
    rxs.push(submit_traced(&mut sched, &mut router, "inv0a inv0b inv0c inv0d inv0e inv0f"));
    rxs.push(submit_traced(&mut sched, &mut router, "dupa dupb dupc dupd"));
    rxs.push(submit_traced(&mut sched, &mut router, "dupa dupb dupc dupd"));
    for i in 0..3 {
        let q = format!("fresh{i}x fresh{i}y fresh{i}z fresh{i}w");
        rxs.push(submit_traced(&mut sched, &mut router, &q));
    }
    sched.drain(&mut router);
    let n = rxs.len();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }

    let total = router.latency.summary("total").unwrap();
    assert_eq!(total.n, n, "exactly one total sample per served request");
    assert_eq!(router.traces.finished(), n as u64);
    let counts = router.traces.pathway_counts();
    let sum: u64 = counts.iter().map(|&(_, c)| c).sum();
    assert_eq!(sum, n as u64, "pathway partition must cover every request");
    let get = |name: &str| counts.iter().find(|&&(k, _)| k == name).unwrap().1;
    assert_eq!(get("miss"), 5, "prime + dup leader + 3 fresh");
    assert_eq!(get("tweak_hit"), 1);
    assert_eq!(get("exact_hit"), 1);
    assert_eq!(get("coalesced"), 1);
}

#[test]
fn ring_capacity_bounds_retained_traces_under_load() {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.trace.ring_capacity = 4;
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    let mut router = Router::with_models(
        embedder,
        Box::new(MockLlm::new("big")),
        Box::new(MockLlm::new("small")),
        cfg,
    );
    let mut sched = Scheduler::new(router.config.scheduler);
    let n = 12;
    let mut rxs = Vec::new();
    for i in 0..n {
        let q = format!("ring{i}a ring{i}b ring{i}c ring{i}d");
        rxs.push(submit_traced(&mut sched, &mut router, &q));
    }
    sched.drain(&mut router);
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    // Every finish is counted; the ring retains only the newest 4, and
    // recent() reports them newest-first (strictly decreasing ids).
    assert_eq!(router.traces.finished(), n as u64);
    let recent = router.traces.recent(usize::MAX);
    assert_eq!(recent.len(), 4, "ring must evict past its capacity");
    for w in recent.windows(2) {
        assert!(w[0].id > w[1].id, "recent() must be newest-first");
    }
}
