//! Token streaming end-to-end: concatenated deltas must be bit-identical
//! to the blocking response on every pathway, scheduler on and off; a
//! dropped receiver cancels the in-flight session; the HTTP/SSE front end
//! speaks the OpenAI chunk shape over a real socket.

use std::sync::mpsc::Receiver;
use std::time::Duration;

use tweakllm::baselines::{FaultPlan, MockLlm};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Pathway, RoutedResponse, Router, StreamEvent};
use tweakllm::faults::FaultMode;
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::util::Json;

fn start_engine(sched: bool, big: MockLlm, small: MockLlm) -> (Engine, EngineHandle) {
    Engine::start(move || {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        cfg.scheduler.enabled = sched;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(embedder, Box::new(big), Box::new(small), cfg))
    })
    .expect("engine start")
}

/// Drain a streamed reply: concatenated non-empty deltas plus the terminal
/// event (`Done` response or `Error` message).
fn drain(rx: Receiver<StreamEvent>) -> (String, Result<RoutedResponse, String>) {
    let mut text = String::new();
    for ev in rx.iter() {
        match ev {
            StreamEvent::Delta(d) => text.push_str(&d),
            StreamEvent::Done(r) => return (text, Ok(r)),
            StreamEvent::Error(m) => return (text, Err(m)),
        }
    }
    (text, Err("stream ended without a terminal event".into()))
}

/// Submit a streamed request and drain it to the terminal event.
fn stream(h: &EngineHandle, q: &str) -> (String, Result<RoutedResponse, String>) {
    drain(h.request_streaming(q).expect("request_streaming"))
}

#[test]
fn stream_concat_matches_blocking_text_on_every_pathway() {
    for sched in [true, false] {
        let big = MockLlm::new("big").with_pace(4, Duration::ZERO);
        let small = MockLlm::new("small").with_pace(4, Duration::ZERO);
        let (_engine, h) = start_engine(sched, big, small);

        let (text, r) = stream(&h, "why is coffee good for health?");
        let r = r.expect("miss completes");
        assert_eq!(r.pathway, Pathway::Miss, "sched={sched}");
        assert!(!text.is_empty(), "sched={sched}: miss streamed nothing");
        assert_eq!(text, r.text, "sched={sched}: miss deltas != blocking text");
        let miss_text = r.text;

        let (text, r) = stream(&h, "why is coffee great for health?");
        let r = r.expect("tweak completes");
        assert_eq!(r.pathway, Pathway::TweakHit, "sched={sched}");
        assert_eq!(text, r.text, "sched={sched}: tweak deltas != blocking text");

        let (text, r) = stream(&h, "why is coffee good for health?");
        let r = r.expect("exact hit completes");
        assert_eq!(r.pathway, Pathway::ExactHit, "sched={sched}");
        assert_eq!(text, r.text, "sched={sched}: exact deltas != blocking text");
        assert_eq!(text, miss_text, "sched={sched}: exact hit must replay cached bytes");

        // The blocking wrapper drains the same transport: same bytes.
        let b = h.request("why is coffee good for health?").unwrap();
        assert_eq!(b.text, miss_text, "sched={sched}");
    }
}

#[test]
fn degraded_stream_replays_cached_text_verbatim() {
    for sched in [true, false] {
        let big = MockLlm::new("big").with_pace(3, Duration::ZERO);
        let plan = FaultPlan::new(|_| FaultMode::Error);
        let small = MockLlm::new("small").with_fault_plan(plan);
        let (_engine, h) = start_engine(sched, big, small);

        let primed = h.request("why is coffee good for health?").unwrap();
        assert_eq!(primed.pathway, Pathway::Miss, "sched={sched}");

        let (text, r) = stream(&h, "why is coffee great for health?");
        let r = r.expect("degraded hit completes");
        assert_eq!(r.pathway, Pathway::DegradedHit, "sched={sched}");
        assert_eq!(text, r.text, "sched={sched}: degraded deltas != blocking text");
        assert_eq!(
            text, primed.text,
            "sched={sched}: degraded hit must replay the raw cached response"
        );
    }
}

#[test]
fn coalesced_follower_stream_matches_leader_bytes() {
    // Slow miss (~160ms) so the duplicate provably attaches mid-flight.
    let big = MockLlm::new("big").with_pace(40, Duration::from_millis(4));
    let small = MockLlm::new("small");
    let (_engine, h) = start_engine(true, big, small);

    let leader_rx = h.request_streaming("what makes glass transparent?").unwrap();
    std::thread::sleep(Duration::from_millis(30));
    let follower_rx = h.request_streaming("what makes glass transparent?").unwrap();

    let leader = std::thread::spawn(move || drain(leader_rx));
    let (f_text, f_r) = drain(follower_rx);
    let (l_text, l_r) = leader.join().unwrap();
    let l_r = l_r.expect("leader completes");
    let f_r = f_r.expect("follower completes");

    assert_eq!(l_text, l_r.text, "leader deltas != blocking text");
    assert_eq!(f_text, f_r.text, "follower deltas != blocking text");
    assert_eq!(
        l_text, f_text,
        "follower must catch up on already-streamed text and then track the leader"
    );
    let stats = h.stats().unwrap();
    assert_eq!(stats.coalesced, 1, "duplicate must coalesce, not regenerate");
    assert_eq!(stats.misses, 1);
}

#[test]
fn dropped_stream_receiver_cancels_and_frees_the_slot() {
    let big = MockLlm::new("big").with_pace(500, Duration::from_millis(2));
    let small = MockLlm::new("small");
    let (_engine, h) = start_engine(true, big, small);

    let rx = h.request_streaming("an answer nobody will wait for").unwrap();
    // Receive at least one real delta so the session is provably decoding.
    let mut saw_text = false;
    for ev in rx.iter() {
        if let StreamEvent::Delta(d) = ev {
            if !d.is_empty() {
                saw_text = true;
                break;
            }
        }
    }
    assert!(saw_text, "no delta before disconnect");
    drop(rx); // client gone

    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let s = h.stats().unwrap();
        if s.cancelled == 1 {
            assert_eq!(s.active_sessions, 0, "cancelled session must free its slot");
            assert_eq!(s.misses, 0, "a cancelled request is not a completed miss");
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "scheduler never observed the disconnect"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // The engine keeps serving after the abandoned session is reaped.
    let r = h.request("a fresh question after the disconnect").unwrap();
    assert_eq!(r.pathway, Pathway::Miss);
}

#[test]
fn every_reply_finishes_exactly_one_trace() {
    let big = MockLlm::new("big").with_pace(3, Duration::ZERO);
    let small = MockLlm::new("small").with_pace(3, Duration::ZERO);
    let (_engine, h) = start_engine(true, big, small);

    let queries = [
        "how do owls rotate their heads?",
        "how do owls turn their heads?",
        "how do owls rotate their heads?",
        "something unrelated entirely",
    ];
    let mut ids = std::collections::HashSet::new();
    for q in queries {
        let (_text, r) = drain(h.request_streaming(q).unwrap());
        let r = r.expect("streamed request completes");
        assert!(r.trace_id > 0, "streamed reply must carry its trace id");
        assert!(ids.insert(r.trace_id), "trace id {} reused", r.trace_id);
    }
    let blocking = h.request("one more blocking request").unwrap();
    assert!(blocking.trace_id > 0);

    let s = h.stats().unwrap();
    assert_eq!(
        s.traces_finished,
        queries.len() as u64 + 1,
        "one reply must finish exactly one trace"
    );
}

fn http_roundtrip(addr: &str, request: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap(); // Connection: close → EOF
    raw
}

fn post(addr: &str, body: &str) -> String {
    let req = format!(
        "POST /v1/chat/completions HTTP/1.1\r\nHost: t\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    http_roundtrip(addr, &req)
}

#[test]
fn sse_endpoint_streams_openai_chunks_over_a_real_socket() {
    let big = MockLlm::new("big").with_pace(6, Duration::ZERO);
    let small = MockLlm::new("small");
    let (_engine, h) = start_engine(true, big, small);
    let http = tweakllm::server::HttpServer::bind("127.0.0.1:0", h).unwrap();
    let addr = http.local_addr().unwrap().to_string();
    let stop = http.shutdown_handle().unwrap();
    let join = std::thread::spawn(move || http.serve());

    let body = r#"{"model":"tweakllm","stream":true,"messages":[{"role":"user","content":"why do cats purr so much?"}]}"#;
    let raw = post(&addr, body);
    assert!(raw.starts_with("HTTP/1.1 200 OK"), "{raw}");
    assert!(raw.contains("text/event-stream"), "{raw}");

    let mut payloads = Vec::new();
    for line in raw.lines() {
        if let Some(p) = line.strip_prefix("data: ") {
            payloads.push(p);
        }
    }
    assert!(payloads.len() >= 3, "expected preamble + deltas + final: {raw}");
    assert_eq!(payloads.last().copied(), Some("[DONE]"));

    let mut text = String::new();
    let mut finish = None;
    let mut pathway = None;
    for p in &payloads[..payloads.len() - 1] {
        let j = Json::parse(p).unwrap();
        assert_eq!(j.get("object").unwrap().str().unwrap(), "chat.completion.chunk");
        let choice = &j.get("choices").unwrap().arr().unwrap()[0];
        if let Some(d) = choice.get("delta").unwrap().opt("content") {
            text.push_str(d.str().unwrap());
        }
        if let Some(f) = choice.opt("finish_reason") {
            finish = Some(f.str().unwrap().to_string());
            let ext = j.get("tweakllm").unwrap();
            pathway = Some(ext.get("pathway").unwrap().str().unwrap().to_string());
            assert!(ext.get("trace_id").unwrap().usize().unwrap() > 0);
            assert!(j.get("usage").unwrap().get("total_tokens").unwrap().f64().unwrap() > 0.0);
        }
    }
    assert_eq!(finish.as_deref(), Some("stop"));
    assert_eq!(pathway.as_deref(), Some("miss"));
    assert!(!text.is_empty());

    // Same question, non-streaming: an exact hit with identical bytes —
    // the server-level identity gate.
    let body2 = r#"{"messages":[{"role":"user","content":"why do cats purr so much?"}]}"#;
    let raw2 = post(&addr, body2);
    let (head, json_body) = raw2.split_once("\r\n\r\n").unwrap();
    assert!(head.contains("200 OK"), "{raw2}");
    let j = Json::parse(json_body).unwrap();
    assert_eq!(j.get("object").unwrap().str().unwrap(), "chat.completion");
    let msg = j.get("choices").unwrap().arr().unwrap()[0].get("message").unwrap().clone();
    assert_eq!(
        msg.get("content").unwrap().str().unwrap(),
        text,
        "streamed concat must equal the blocking reply body"
    );
    assert_eq!(
        j.get("tweakllm").unwrap().get("pathway").unwrap().str().unwrap(),
        "exact_hit"
    );

    stop.signal();
    let _ = join.join().unwrap();
}

#[test]
fn http_front_end_rejects_unknown_paths_methods_and_bodies() {
    let big = MockLlm::new("big");
    let small = MockLlm::new("small");
    let (_engine, h) = start_engine(false, big, small);
    let http = tweakllm::server::HttpServer::bind("127.0.0.1:0", h).unwrap();
    let addr = http.local_addr().unwrap().to_string();
    let stop = http.shutdown_handle().unwrap();
    let join = std::thread::spawn(move || http.serve());

    let raw = http_roundtrip(&addr, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(raw.starts_with("HTTP/1.1 404"), "{raw}");

    let raw = http_roundtrip(&addr, "GET /v1/chat/completions HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(raw.starts_with("HTTP/1.1 405"), "{raw}");

    let raw = post(&addr, "{\"messages\": []}");
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("user message"), "{raw}");

    let raw = post(&addr, "this is not json");
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");

    stop.signal();
    let _ = join.join().unwrap();
}
