//! Coordinator integration tests: the Figure-1 routing logic over a native
//! embedder + mock LLMs (no artifacts needed), plus randomized invariant
//! ("property") tests over the cache/router state machine.

use tweakllm::baselines::MockLlm;
use tweakllm::cache::EvictionPolicy;
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Pathway, Router};
use tweakllm::llm::{LanguageModel, LlmResponse, TweakPrompt};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::util::Rng;

fn test_config() -> Config {
    let mut c = Config::paper();
    c.index.kind = IndexKindConfig::Flat;
    c
}

fn make_router(cfg: Config) -> Router {
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    Router::with_models(
        embedder,
        Box::new(MockLlm::new("big")),
        Box::new(MockLlm::new("small")),
        cfg,
    )
}

#[test]
fn cold_cache_routes_to_big() {
    let mut r = make_router(test_config());
    let resp = r.handle("why is coffee good for health?").unwrap();
    assert_eq!(resp.pathway, Pathway::Miss);
    assert!(resp.text.contains("big-fresh"));
    assert_eq!(r.cache().len(), 1);
}

#[test]
fn paraphrase_routes_to_tweak() {
    let mut r = make_router(test_config());
    r.handle("why is coffee good for health?").unwrap();
    let resp = r.handle("why is coffee great for health?").unwrap();
    assert_eq!(resp.pathway, Pathway::TweakHit, "sim={:?}", resp.similarity);
    assert!(resp.text.contains("small-tweaked"));
    assert_eq!(
        resp.cached_query.as_deref(),
        Some("why is coffee good for health?")
    );
    // tweak hits must NOT grow the cache (paper: only Big responses cached)
    assert_eq!(r.cache().len(), 1);
}

#[test]
fn unrelated_query_misses() {
    let mut r = make_router(test_config());
    r.handle("why is coffee good for health?").unwrap();
    let resp = r.handle("write a poem about glaciers").unwrap();
    assert_eq!(resp.pathway, Pathway::Miss);
    assert_eq!(r.cache().len(), 2);
}

#[test]
fn exact_fast_path() {
    let mut cfg = test_config();
    cfg.exact_match_fast_path = true;
    let mut r = make_router(cfg);
    let first = r.handle("why is rust fast?").unwrap();
    let again = r.handle("Why is   RUST fast?").unwrap(); // normalized match
    assert_eq!(again.pathway, Pathway::ExactHit);
    assert_eq!(again.text, first.text); // verbatim
    assert_eq!(again.usage.output_tokens, 0); // free
    assert_eq!(r.ledger.requests_free, 1);
}

#[test]
fn exact_fast_path_disabled_by_default_paper_config() {
    // Table 1 implementation tweaks every hit, even identical text.
    let mut r = make_router(test_config());
    r.handle("why is rust fast?").unwrap();
    let again = r.handle("why is rust fast?").unwrap();
    assert_eq!(again.pathway, Pathway::TweakHit);
    assert_eq!(again.similarity.map(|s| s > 0.999), Some(true));
}

#[test]
fn threshold_one_never_tweaks_paraphrases() {
    let mut cfg = test_config();
    cfg.similarity_threshold = 1.01; // unreachable
    let mut r = make_router(cfg);
    r.handle("why is coffee good for health?").unwrap();
    let resp = r.handle("why is coffee great for health?").unwrap();
    assert_eq!(resp.pathway, Pathway::Miss);
}

#[test]
fn cost_ledger_tracks_pathways() {
    let mut r = make_router(test_config());
    r.handle("why is coffee good for health?").unwrap(); // big
    r.handle("why is coffee great for health?").unwrap(); // small
    assert_eq!(r.ledger.requests_big, 1);
    assert_eq!(r.ledger.requests_small, 1);
    let cost = r.ledger.dollars(&r.config.cost);
    let base = r.ledger.baseline_dollars(&r.config.cost);
    assert!(cost < base, "cost={cost} base={base}");
}

#[test]
fn tweak_prompt_carries_cached_pair() {
    // Intercept the small model to check the prompt contents.
    struct Capture(Vec<TweakPrompt>);
    impl LanguageModel for Capture {
        fn name(&self) -> &str {
            "capture"
        }
        fn respond(&mut self, _q: &str) -> anyhow::Result<LlmResponse> {
            unreachable!("small model never called on miss pathway")
        }
        fn tweak(&mut self, p: &TweakPrompt) -> anyhow::Result<LlmResponse> {
            self.0.push(p.clone());
            Ok(LlmResponse {
                text: "t".into(),
                usage: Default::default(),
                restored_tokens: 0,
                prefill_micros: 0,
                decode_micros: 0,
            })
        }
    }
    let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
    let mut r = Router::with_models(
        embedder,
        Box::new(MockLlm::new("big")),
        Box::new(Capture(Vec::new())),
        test_config(),
    );
    r.handle("why is coffee good for health?").unwrap();
    r.handle("why is coffee great for health?").unwrap();
    // the captured prompt is inside the router; verify via counters instead
    assert_eq!(r.counters.get("tweak_hits"), 1);
}

#[test]
fn bounded_cache_evicts_and_keeps_serving() {
    let mut cfg = test_config();
    cfg.eviction.policy = EvictionPolicy::Lru;
    cfg.eviction.capacity = 8;
    let mut r = make_router(cfg);
    for i in 0..40 {
        r.handle(&format!("zeta{i} kappa{i} theta{i} omega{i}")).unwrap();
    }
    assert!(r.cache().len() <= 8);
    assert!(r.cache().stats().evictions >= 32);
}

// ---------------------------------------------------------------------------
// Randomized invariant tests (hand-rolled property testing: proptest is not
// in the offline vendor set; seeds are fixed so failures reproduce).
// ---------------------------------------------------------------------------

/// Generate a random query from a small vocabulary so collisions happen.
fn random_query(rng: &mut Rng) -> String {
    let words = ["why", "is", "coffee", "tea", "rust", "good", "bad", "for",
        "health", "sleep", "speed", "explain", "the", "of", "best"];
    let n = rng.range(3, 9);
    (0..n).map(|_| *rng.choose(&words)).collect::<Vec<_>>().join(" ")
}

#[test]
fn invariant_every_request_gets_exactly_one_pathway() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let mut r = make_router(test_config());
        let n = 120;
        for _ in 0..n {
            let q = random_query(&mut rng);
            let resp = r.handle(&q).unwrap();
            assert!(!resp.text.is_empty());
        }
        let c = &r.counters;
        assert_eq!(
            c.get("requests"),
            c.get("tweak_hits") + c.get("exact_hits") + c.get("misses"),
            "pathway counts must partition requests (seed {seed})"
        );
        // cache grows exactly with misses (append-only config)
        assert_eq!(r.cache().len() as u64, c.get("misses"));
    }
}

#[test]
fn invariant_similarity_bounds_and_threshold_consistency() {
    for seed in 5..10u64 {
        let mut rng = Rng::new(seed);
        let mut cfg = test_config();
        cfg.similarity_threshold = 0.7 + 0.25 * rng.f64() as f32;
        let tau = cfg.similarity_threshold;
        let mut r = make_router(cfg);
        for _ in 0..100 {
            let q = random_query(&mut rng);
            let resp = r.handle(&q).unwrap();
            if let Some(s) = resp.similarity {
                assert!((-1.01..=1.01).contains(&s), "similarity out of range: {s}");
                match resp.pathway {
                    Pathway::TweakHit => assert!(s >= tau, "tweak below threshold"),
                    Pathway::Miss => assert!(s < tau, "miss above threshold"),
                    Pathway::ExactHit => {}
                }
            } else {
                assert_eq!(resp.pathway, Pathway::Miss, "no similarity => cold miss");
            }
        }
    }
}

#[test]
fn invariant_deterministic_given_seed_and_workload() {
    let run = || {
        let mut rng = Rng::new(42);
        let mut r = make_router(test_config());
        let mut log = Vec::new();
        for _ in 0..80 {
            let q = random_query(&mut rng);
            let resp = r.handle(&q).unwrap();
            log.push((q, format!("{:?}", resp.pathway), resp.text));
        }
        log
    };
    assert_eq!(run(), run());
}

#[test]
fn invariant_eviction_never_breaks_serving() {
    for (pi, policy) in [
        EvictionPolicy::Lru,
        EvictionPolicy::Lfu,
        EvictionPolicy::Fifo,
    ]
    .iter()
    .enumerate()
    {
        let mut rng = Rng::new(100 + pi as u64);
        let mut cfg = test_config();
        cfg.eviction.policy = *policy;
        cfg.eviction.capacity = 5;
        cfg.exact_match_fast_path = true;
        let mut r = make_router(cfg);
        for _ in 0..200 {
            let q = random_query(&mut rng);
            let resp = r.handle(&q).unwrap();
            assert!(!resp.text.is_empty());
            assert!(r.cache().len() <= 5, "{policy:?} exceeded capacity");
        }
    }
}
