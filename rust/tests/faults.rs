//! Chaos matrix for the fault-tolerance layer: each test knocks out (or
//! degrades) one backend through the `FaultSwitch` decorators and asserts
//! the degradation ladder lands on the documented rung — and that every
//! request still gets exactly one reply and finishes exactly one trace.
//!
//! NB: retried and failed requests deliberately violate span well-formedness
//! (a re-queued job opens a second QueueWait under the same trace), so these
//! tests assert on tags and counters, never on span nesting.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use tweakllm::baselines::{FaultPlan, MockLlm};
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Pathway, Router};
use tweakllm::faults::{FaultMode, FaultSwitch, FaultyEmbedder, FaultyLlm};
use tweakllm::llm::LanguageModel;
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::trace::TraceTag;

/// Engine with every backend behind a fault decorator, each on its own
/// switch so a test can take one subsystem down while the rest stay up.
struct ChaosStack {
    _engine: Engine,
    handle: EngineHandle,
    embed: FaultSwitch,
    small: FaultSwitch,
    #[allow(dead_code)]
    big: FaultSwitch,
}

fn chaos_stack(big_llm: MockLlm, tune: impl FnOnce(&mut Config)) -> ChaosStack {
    let mut cfg = Config::paper();
    cfg.index.kind = IndexKindConfig::Flat;
    cfg.exact_match_fast_path = true;
    cfg.scheduler.enabled = true;
    tune(&mut cfg);
    let embed = FaultSwitch::healthy();
    let small = FaultSwitch::healthy();
    let big = FaultSwitch::healthy();
    let (e, s, b) = (embed.clone(), small.clone(), big.clone());
    let (engine, handle) = Engine::start(move || {
        let embedder: Box<dyn TextEmbedder> =
            Box::new(FaultyEmbedder::new(Box::new(NativeBowEmbedder::new(128, 7)), e));
        let big: Box<dyn LanguageModel> = Box::new(FaultyLlm::new(Box::new(big_llm), b));
        let small: Box<dyn LanguageModel> =
            Box::new(FaultyLlm::new(Box::new(MockLlm::new("small")), s));
        Ok(Router::with_models(embedder, big, small, cfg))
    })
    .expect("engine start");
    ChaosStack { _engine: engine, handle, embed, small, big }
}

/// Prime query: six disjoint synthetic words, same scheme as the scheduler
/// identity tests.
fn prime(topic: usize) -> String {
    format!("q{topic}a q{topic}b q{topic}c q{topic}d q{topic}e q{topic}f")
}

/// Paraphrase sharing 5/6 words with its prime — a guaranteed tweak-hit
/// against the `NativeBowEmbedder` at the paper threshold.
fn paraphrase(topic: usize, variant: usize) -> String {
    format!("q{topic}a q{topic}b q{topic}c q{topic}d q{topic}e v{variant}")
}

/// Rung 1: tweak-LLM outage. A would-be tweak-hit is degraded to the raw
/// cached response — tagged `degraded_hit` in both stats and traces — and
/// the pathway heals as soon as the backend does.
#[test]
fn tweak_outage_degrades_to_raw_cached_response() {
    let stack = chaos_stack(MockLlm::new("big"), |_| {});
    let primed = stack.handle.request(&prime(0)).unwrap();
    assert_eq!(primed.pathway, Pathway::Miss);

    stack.small.set(FaultMode::Error);
    let r = stack.handle.request(&paraphrase(0, 0)).unwrap();
    assert_eq!(r.pathway, Pathway::DegradedHit);
    assert_eq!(r.text, primed.text, "degraded rung serves the raw cached response");
    assert_eq!(r.cache_entry, primed.cache_entry);

    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.degraded_hits, 1);
    assert_eq!(stats.tweak_hits, 0);
    let report = stack.handle.traces(16).unwrap();
    let t = report
        .traces
        .iter()
        .find(|t| t.query == paraphrase(0, 0))
        .expect("degraded request finished a trace");
    assert_eq!(t.tag, TraceTag::DegradedHit);

    stack.small.set(FaultMode::Healthy);
    let healed = stack.handle.request(&paraphrase(0, 1)).unwrap();
    assert_eq!(healed.pathway, Pathway::TweakHit, "ladder steps back up once healthy");
}

/// Rung 1, hang shape: a tweak session that never finishes is reaped by the
/// `tweak_timeout_ms` overrun check and degraded — bounded time, no wedge.
#[test]
fn hung_tweak_times_out_and_degrades() {
    let stack = chaos_stack(MockLlm::new("big"), |cfg| {
        cfg.faults.tweak_timeout_ms = 40;
    });
    let primed = stack.handle.request(&prime(0)).unwrap();

    stack.small.set(FaultMode::Hang);
    let t0 = Instant::now();
    let r = stack.handle.request(&paraphrase(0, 0)).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(10), "hung tweak must be reaped, not waited");
    assert_eq!(r.pathway, Pathway::DegradedHit);
    assert_eq!(r.text, primed.text);
    assert_eq!(stack.handle.stats().unwrap().degraded_hits, 1);
}

/// Rung 2: embedder outage. The cache tier is bypassed entirely — the query
/// goes straight to the Big LLM, nothing is inserted (there is no embedding
/// to index), and the cache serves again once the embedder heals.
#[test]
fn embedder_outage_bypasses_cache() {
    let stack = chaos_stack(MockLlm::new("big"), |_| {});
    stack.handle.request(&prime(0)).unwrap();

    stack.embed.set(FaultMode::Error);
    let r = stack.handle.request(&paraphrase(0, 0)).unwrap();
    assert_eq!(r.pathway, Pathway::Miss, "embed outage bypasses straight to the miss path");

    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.embed_bypasses, 1);
    assert_eq!(stats.cache_size, 1, "bypassed miss must not insert a row");

    stack.embed.set(FaultMode::Healthy);
    let healed = stack.handle.request(&paraphrase(0, 1)).unwrap();
    assert_eq!(healed.pathway, Pathway::TweakHit, "cache tier intact behind the outage");
}

/// Rung 3: flaky Big LLM. A failed first attempt is retried from the back
/// of the queue; the retry re-issues the same prompt, so the served text is
/// bit-identical to what a first-try success would have produced.
#[test]
fn flaky_big_llm_retry_matches_first_try_response() {
    let flaky = MockLlm::new("big").with_fault_plan(FaultPlan::fail_first(1));
    let stack = chaos_stack(flaky, |_| {});
    let r = stack.handle.request(&prime(3)).unwrap();
    assert_eq!(r.pathway, Pathway::Miss);

    let reference = chaos_stack(MockLlm::new("big"), |_| {});
    let want = reference.handle.request(&prime(3)).unwrap();
    assert_eq!(r.text, want.text, "retry must be bit-identical to a first-try success");

    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.miss_retries, 1);
    assert_eq!(stats.misses, 1, "a retried miss is still one miss");
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.cache_size, 1, "the retried generation inserts normally");
}

/// Rung 3, terminal shape: when every attempt fails, the caller gets a
/// structured error (exactly one), the failure is traced, and the engine
/// keeps serving.
#[test]
fn exhausted_retries_return_structured_error() {
    // 1 + miss_retries=2 attempts, all scripted to fail; call 3 heals.
    let flaky = MockLlm::new("big").with_fault_plan(FaultPlan::fail_first(3));
    let stack = chaos_stack(flaky, |_| {});
    let err = stack.handle.request(&prime(0)).expect_err("all attempts failed");
    let msg = format!("{err:#}");
    assert!(msg.contains("generation failed"), "structured error shape: {msg}");

    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.miss_retries, 2, "full retry budget was spent");
    let report = stack.handle.traces(16).unwrap();
    assert_eq!(report.traces[0].tag, TraceTag::Failed);

    let ok = stack.handle.request(&prime(1)).unwrap();
    assert_eq!(ok.pathway, Pathway::Miss, "engine serves normally after the outage");
}

/// Breaker lifecycle end-to-end: repeated tweak failures trip the small-LLM
/// breaker open (later hits degrade without touching the backend), and a
/// healthy probe after the cool-down closes it again.
#[test]
fn tweak_breaker_opens_and_recovers_through_half_open() {
    let stack = chaos_stack(MockLlm::new("big"), |cfg| {
        cfg.faults.breaker_window = 4;
        cfg.faults.breaker_min_samples = 2;
        cfg.faults.breaker_open_ms = 100;
        cfg.faults.breaker_half_open_probes = 1;
    });
    stack.handle.request(&prime(0)).unwrap();

    stack.small.set(FaultMode::Error);
    for v in 0..2 {
        let r = stack.handle.request(&paraphrase(0, v)).unwrap();
        assert_eq!(r.pathway, Pathway::DegradedHit);
    }
    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.breaker_small, "open", "two failures over min_samples=2 trip it");
    assert!(stats.breaker_trips >= 1);

    // Open gate: still degraded, no backend call needed.
    let gated = stack.handle.request(&paraphrase(0, 2)).unwrap();
    assert_eq!(gated.pathway, Pathway::DegradedHit);

    // Heal the backend, let the cool-down elapse: the next hit is the
    // half-open probe, succeeds, and closes the breaker.
    stack.small.set(FaultMode::Healthy);
    std::thread::sleep(Duration::from_millis(150));
    let probe = stack.handle.request(&paraphrase(0, 3)).unwrap();
    assert_eq!(probe.pathway, Pathway::TweakHit);
    assert_eq!(stack.handle.stats().unwrap().breaker_small, "closed");
}

/// Deadline shedding: requests that outlive `request_deadline_ms` are
/// answered with a structured error at the next stage boundary — every
/// caller hears back, every shed request still finishes one trace.
#[test]
fn expired_deadlines_shed_with_structured_errors() {
    let slow = MockLlm::new("big").with_pace(60, Duration::from_millis(2));
    let stack = chaos_stack(slow, |cfg| {
        cfg.faults.request_deadline_ms = 40;
    });

    let n = 3;
    let (done_tx, done_rx) = mpsc::channel();
    for i in 0..n {
        let h = stack.handle.clone();
        let done = done_tx.clone();
        std::thread::spawn(move || {
            let _ = done.send(h.request(&format!("slow{i}a slow{i}b slow{i}c slow{i}d")));
        });
    }
    for _ in 0..n {
        let r = done_rx
            .recv_timeout(Duration::from_secs(20))
            .expect("a shed request must still reply");
        let err = r.expect_err("120ms generation cannot meet a 40ms deadline");
        assert!(format!("{err:#}").contains("deadline"), "unexpected error: {err:#}");
    }

    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.requests, n as u64);
    assert_eq!(stats.shed, n as u64);
    assert_eq!(stats.traces_finished, n as u64, "one trace per shed request");
    let report = stack.handle.traces(16).unwrap();
    assert!(report.traces.iter().all(|t| t.tag == TraceTag::Failed));
}

/// The umbrella invariant: a workload that crosses every rung — healthy,
/// tweak outage, embedder outage, healed — yields exactly one reply and
/// exactly one trace per request, with the pathway partition adding up.
#[test]
fn every_request_gets_one_reply_and_one_trace_across_the_ladder() {
    let stack = chaos_stack(MockLlm::new("big"), |_| {});
    let mut sent: Vec<String> = Vec::new();
    let mut request = |q: String, want: Pathway| {
        let r = stack.handle.request(&q).unwrap();
        assert_eq!(r.pathway, want, "query {q}");
        sent.push(q);
    };

    request(prime(0), Pathway::Miss);
    request(prime(1), Pathway::Miss);
    // Healthy rung.
    request(paraphrase(0, 0), Pathway::TweakHit);
    request("m0a m0b m0c m0d m0e m0f".into(), Pathway::Miss);
    // Tweak outage rung.
    stack.small.set(FaultMode::Error);
    request(paraphrase(0, 1), Pathway::DegradedHit);
    request(paraphrase(1, 0), Pathway::DegradedHit);
    stack.small.set(FaultMode::Healthy);
    // Embedder outage rung.
    stack.embed.set(FaultMode::Error);
    request(paraphrase(1, 1), Pathway::Miss); // would tweak; bypasses instead
    request("m1a m1b m1c m1d m1e m1f".into(), Pathway::Miss);
    stack.embed.set(FaultMode::Healthy);
    // Healed.
    request(paraphrase(1, 2), Pathway::TweakHit);
    request("m2a m2b m2c m2d m2e m2f".into(), Pathway::Miss);

    let stats = stack.handle.stats().unwrap();
    assert_eq!(stats.requests, sent.len() as u64);
    assert_eq!(stats.traces_finished, sent.len() as u64, "exactly one trace per request");
    assert_eq!(stats.degraded_hits, 2);
    assert_eq!(stats.embed_bypasses, 2);
    assert_eq!(stats.tweak_hits, 2);
    assert_eq!(stats.misses, 6, "2 primes + 2 fresh misses + 2 embed bypasses");
    assert_eq!(stats.failed + stats.shed, 0, "nothing terminal in this mix");
    assert_eq!(stats.cache_size, 5, "bypassed misses insert nothing");

    // One trace per query, tags matching the stats partition.
    let report = stack.handle.traces(32).unwrap();
    let mut traced: Vec<String> = report.traces.iter().map(|t| t.query.clone()).collect();
    traced.sort();
    let mut expect = sent.clone();
    expect.sort();
    assert_eq!(traced, expect);
    let degraded = report.traces.iter().filter(|t| t.tag == TraceTag::DegradedHit).count();
    assert_eq!(degraded as u64, stats.degraded_hits);
}
