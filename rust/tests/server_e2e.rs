//! Engine + TCP server end-to-end over mock models (no artifacts needed):
//! real sockets, real engine thread, real dynamic batching.

use tweakllm::baselines::MockLlm;
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::server::{Client, Server, Shutdown};

fn start_stack() -> (tweakllm::coordinator::Engine, EngineHandle, String, Shutdown, std::thread::JoinHandle<anyhow::Result<()>>) {
    let (engine, handle) = Engine::start(|| {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(
            embedder,
            Box::new(MockLlm::new("big")),
            Box::new(MockLlm::new("small")),
            cfg,
        ))
    })
    .expect("engine start");
    let server = Server::bind("127.0.0.1:0", handle.clone()).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.shutdown_handle().unwrap();
    let join = std::thread::spawn(move || server.serve());
    (engine, handle, addr, stop, join)
}

#[test]
fn query_roundtrip_over_tcp() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();

    let r1 = client.query("why is coffee good for health?").unwrap();
    assert_eq!(r1.get("pathway").unwrap().str().unwrap(), "miss");
    assert!(r1.get("text").unwrap().str().unwrap().contains("big-fresh"));

    let r2 = client.query("why is coffee great for health?").unwrap();
    assert_eq!(r2.get("pathway").unwrap().str().unwrap(), "tweak_hit");
    let sim = r2.get("similarity").unwrap().f64().unwrap();
    assert!(sim >= 0.7, "sim={sim}");

    let r3 = client.query("why is coffee good for health?").unwrap();
    assert_eq!(r3.get("pathway").unwrap().str().unwrap(), "exact_hit");

    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn stats_endpoint_reports_counters() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    client.query("explain the soil of tomatoes").unwrap();
    client.query("explain the soil of tomatoes please").unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("requests").unwrap().f64().unwrap() as u64, 2);
    assert_eq!(stats.get("cache_size").unwrap().f64().unwrap() as u64, 1);
    let hits = stats.get("tweak_hits").unwrap().f64().unwrap()
        + stats.get("exact_hits").unwrap().f64().unwrap();
    assert_eq!(hits as u64, 1);
    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn stats_surfaces_latency_table_and_persist_fields() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    client.query("tell me about the moons of jupiter").unwrap();
    let stats = client.stats().unwrap();
    // latency_table: collected in EngineStats and now surfaced remotely.
    let table = stats.get("latency_table").unwrap().str().unwrap().to_string();
    assert!(table.contains("stage"), "missing header: {table}");
    assert!(table.contains("total"), "missing total row: {table}");
    // Persistence is disabled in this stack: fields present, zeroed.
    // Batch occupancy fields are surfaced even when batched decode is off
    // (mocks without a pool): present and zeroed.
    assert_eq!(stats.get("batched_steps").unwrap().f64().unwrap() as u64, 0);
    assert_eq!(stats.get("mean_active_slots").unwrap().f64().unwrap(), 0.0);
    assert!(!stats.get("persist_enabled").unwrap().bool().unwrap());
    assert_eq!(stats.get("wal_bytes").unwrap().f64().unwrap() as u64, 0);
    assert_eq!(stats.get("recovered_entries").unwrap().f64().unwrap() as u64, 0);
    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn admin_snapshot_verb_answers_on_ephemeral_stack() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    client.query("what is a semaphore").unwrap();
    let resp = client.snapshot().unwrap();
    // No [persist] config: the verb reports snapshot=false, still counts
    // live entries, and must not error.
    assert!(!resp.get("snapshot").unwrap().bool().unwrap());
    assert_eq!(resp.get("entries").unwrap().f64().unwrap() as u64, 1);
    let resp = client
        .roundtrip(&tweakllm::util::Json::obj_from(vec![(
            "admin",
            tweakllm::util::Json::s("reboot"),
        )]))
        .unwrap();
    assert!(resp.opt("error").is_some(), "unknown admin verbs must error");
    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn slow_writer_survives_read_timeouts() {
    // The connection loop polls the stop flag on a read timeout; bytes of a
    // partial line consumed before the timeout must be retained, not lost.
    use std::io::{BufRead, BufReader, Write};
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let req = "{\"query\": \"why is the sky blue on earth?\"}\n";
    let (head, tail) = req.split_at(14);
    stream.write_all(head.as_bytes()).unwrap();
    stream.flush().unwrap();
    // Longer than the server's 100ms read-poll interval.
    std::thread::sleep(std::time::Duration::from_millis(350));
    stream.write_all(tail.as_bytes()).unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    let resp = tweakllm::util::Json::parse(&line).unwrap();
    assert_eq!(resp.get("pathway").unwrap().str().unwrap(), "miss");
    stop.signal();
    drop(stream);
    let _ = join.join().unwrap();
}

#[test]
fn idle_connection_does_not_block_stop() {
    // Regression: an idle connection used to pin its thread in a blocking
    // read_line forever. With the read timeout it observes the stop flag.
    let (_engine, _handle, addr, stop, join) = start_stack();
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    // Never send anything; raise stop while the connection is idle.
    std::thread::sleep(std::time::Duration::from_millis(50));
    stop.signal();
    let _ = join.join().unwrap(); // accept loop exits
    // The connection thread exits on its next poll tick; the server closing
    // our socket (EOF) is observable within a couple of poll intervals.
    use std::io::Read;
    let mut s = stream;
    s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 16];
    match s.read(&mut buf) {
        Ok(0) => {}                 // clean EOF: connection thread exited
        Ok(_) => panic!("unexpected data on idle connection"),
        Err(e) => panic!("expected EOF after stop, got {e}"),
    }
}

#[test]
fn shutdown_wakes_blocking_accept_without_clients() {
    // The accept loop now blocks in `accept` (no 5ms sleep poll quantizing
    // cold-connect latency); `Shutdown::signal` must wake it with a
    // self-connect even when no client ever connected.
    let (_engine, _handle, _addr, stop, join) = start_stack();
    std::thread::sleep(std::time::Duration::from_millis(20));
    let t0 = std::time::Instant::now();
    stop.signal();
    join.join().unwrap().unwrap();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(2),
        "signal must wake the blocked accept promptly"
    );
}

#[test]
fn cold_connects_each_get_served() {
    // Every fresh connection must be accepted and served the moment it
    // arrives (connect → response works back to back, no stranded accepts).
    let (_engine, _handle, addr, stop, join) = start_stack();
    for i in 0..10 {
        let mut client = Client::connect(&addr).unwrap();
        let r = client.query(&format!("cold connect probe {i}")).unwrap();
        assert!(r.opt("pathway").is_some(), "{}", r.to_string());
    }
    stop.signal();
    let _ = join.join().unwrap();
}

#[test]
fn malformed_request_reports_error_not_crash() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    let resp = client
        .roundtrip(&tweakllm::util::Json::obj_from(vec![(
            "nonsense",
            tweakllm::util::Json::num(1.0),
        )]))
        .unwrap();
    assert!(resp.opt("error").is_some());
    // server still alive afterwards
    let ok = client.query("hello there").unwrap();
    assert!(ok.opt("pathway").is_some());
    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn concurrent_clients_all_served() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut joins = Vec::new();
    for c in 0..4 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut served = 0;
            for i in 0..10 {
                let r = client.query(&format!("client {c} question {i} about topic {i}")).unwrap();
                assert!(r.opt("pathway").is_some(), "{}", r.to_string());
                served += 1;
            }
            served
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 40);
    stop.signal();
    let _ = join.join().unwrap();
}

#[test]
fn total_micros_includes_queue_wait_behind_slow_generation() {
    // Regression: `Engine::flush` used to stamp t_start *after*
    // `Batcher::drain`, so a request that sat in the channel behind a slow
    // Big-LLM generation reported ~0us (exact hits especially). Latency is
    // now measured from each request's enqueue instant.
    use std::time::{Duration, Instant};
    use tweakllm::coordinator::Pathway;
    use tweakllm::llm::{LanguageModel, LlmResponse, TweakPrompt};

    /// Mock Big LLM that holds the engine thread for a fixed wall time and
    /// signals the instant each generation starts (so the test can submit
    /// a request guaranteed to queue behind one — no scheduling races).
    struct SlowLlm {
        inner: MockLlm,
        delay: Duration,
        generating: std::sync::mpsc::Sender<()>,
    }
    impl LanguageModel for SlowLlm {
        fn name(&self) -> &str {
            self.inner.name()
        }
        fn respond(&mut self, query: &str) -> anyhow::Result<LlmResponse> {
            let _ = self.generating.send(());
            std::thread::sleep(self.delay);
            self.inner.respond(query)
        }
        fn tweak(&mut self, prompt: &TweakPrompt) -> anyhow::Result<LlmResponse> {
            let _ = self.generating.send(());
            std::thread::sleep(self.delay);
            self.inner.tweak(prompt)
        }
    }

    let (gen_tx, gen_rx) = std::sync::mpsc::channel::<()>();
    let (_engine, handle) = Engine::start(move || {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(
            embedder,
            Box::new(SlowLlm {
                inner: MockLlm::new("big"),
                delay: Duration::from_millis(80),
                generating: gen_tx,
            }),
            Box::new(MockLlm::new("small")),
            cfg,
        ))
    })
    .expect("engine start");

    // Prime the cache so the later repeat is an exact hit (consume the
    // prime generation's start signal).
    handle.request("what is a mutex in rust").unwrap();
    gen_rx.recv().expect("prime generation signal");

    // Occupy the engine with a slow miss, then submit an exact-hit repeat
    // that has to wait in the channel behind it.
    let h2 = handle.clone();
    let slow = std::thread::spawn(move || h2.request("explain reader writer locks").unwrap());
    // Block until the engine is provably INSIDE the slow generation (the
    // signal fires just before its 80ms sleep), then queue the exact hit.
    gen_rx.recv().expect("slow generation signal");
    let t0 = Instant::now();
    let exact = handle.request("what is a mutex in rust").unwrap();
    let wall = t0.elapsed().as_micros();
    let slow_resp = slow.join().unwrap();
    assert_eq!(slow_resp.pathway, Pathway::Miss);

    assert_eq!(exact.pathway, Pathway::ExactHit);
    assert!(
        exact.total_micros >= 40_000,
        "exact hit must report its queue wait, got {}us",
        exact.total_micros
    );
    // sanity: the report can't exceed what the client actually observed
    assert!(
        exact.total_micros <= wall + 10_000,
        "reported {}us > observed {}us",
        exact.total_micros,
        wall
    );
}

#[test]
fn trace_verb_returns_tagged_span_trees_for_all_pathways() {
    // Mixed workload over real sockets: one miss, one tweak-hit paraphrase,
    // one exact repeat — then pull the span traces back over the wire.
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    client.query("why is coffee good for health?").unwrap(); // miss
    client.query("why is coffee great for health?").unwrap(); // tweak
    client.query("why is coffee good for health?").unwrap(); // exact

    let report = client.trace(16).unwrap();
    assert!(report.get("finished").unwrap().f64().unwrap() as u64 >= 3);
    let traces = report.get("traces").unwrap().arr().unwrap();
    assert!(traces.len() >= 3, "got {} traces", traces.len());

    // Newest first: [exact_hit, tweak_hit, miss].
    let pathways: Vec<&str> = traces
        .iter()
        .take(3)
        .map(|t| t.get("pathway").unwrap().str().unwrap())
        .collect();
    assert_eq!(pathways, vec!["exact_hit", "tweak_hit", "miss"]);

    for t in traces.iter().take(3) {
        let pathway = t.get("pathway").unwrap().str().unwrap();
        let total = t.get("total_us").unwrap().f64().unwrap();
        assert!(total > 0.0);
        let spans = t.get("spans").unwrap().arr().unwrap();
        assert!(!spans.is_empty(), "{pathway} trace has no spans");
        let mut prev_start = 0.0;
        let mut stages = Vec::new();
        for s in spans {
            let start = s.get("start_us").unwrap().f64().unwrap();
            let end = s.get("end_us").unwrap().f64().unwrap();
            assert!(start >= prev_start, "spans must be sorted by start");
            assert!(end >= start && end <= total);
            prev_start = start;
            stages.push(s.get("stage").unwrap().str().unwrap().to_string());
        }
        // Every pathway passes through ingest and reply; the route span
        // carries the similarity that also sits on the trace.
        assert!(stages.iter().any(|s| s == "ingest"), "{pathway}: {stages:?}");
        assert!(stages.iter().any(|s| s == "reply"), "{pathway}: {stages:?}");
        assert!(stages.iter().any(|s| s == "route"), "{pathway}: {stages:?}");
        let sim = t.opt("similarity").map(|s| s.f64().unwrap());
        match pathway {
            "exact_hit" => assert_eq!(sim, Some(1.0)),
            "tweak_hit" => {
                assert!(sim.unwrap() >= 0.7, "tweak sim {sim:?}");
                for stage in ["embed", "search", "prefill", "decode"] {
                    assert!(stages.iter().any(|s| s == stage), "{pathway}: {stages:?}");
                }
            }
            "miss" => {
                for stage in ["embed", "search", "decode", "cache_insert"] {
                    assert!(stages.iter().any(|s| s == stage), "{pathway}: {stages:?}");
                }
            }
            other => panic!("unexpected pathway {other}"),
        }
    }
    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn stats_reports_per_stage_quantiles_from_histograms() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    client.query("how do glaciers carve valleys").unwrap();
    client.query("how do glaciers carve valleys").unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("traces_finished").unwrap().f64().unwrap() as u64, 2);
    let stages = stats.get("stages").unwrap().arr().unwrap();
    assert!(!stages.is_empty());
    for row in stages {
        let p50 = row.get("p50_us").unwrap().f64().unwrap();
        let p99 = row.get("p99_us").unwrap().f64().unwrap();
        assert!(row.get("n").unwrap().f64().unwrap() >= 1.0);
        assert!(p50 >= 0.0 && p99 >= p50 * 0.99, "p50={p50} p99={p99}");
        assert!(row.get("stage").unwrap().str().is_ok());
        assert!(row.get("pathway").unwrap().str().is_ok());
    }
    // one "total" row per pathway observed (miss, then exact repeat)
    let total_paths: Vec<&str> = stages
        .iter()
        .filter(|r| r.get("stage").unwrap().str().unwrap() == "total")
        .map(|r| r.get("pathway").unwrap().str().unwrap())
        .collect();
    assert!(total_paths.contains(&"miss"), "{total_paths:?}");
    assert!(total_paths.contains(&"exact_hit"), "{total_paths:?}");
    stop.signal();
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn oversized_line_gets_structured_error_reply() {
    // A request line past the cap must get a structured JSON refusal, not a
    // silent connection drop (and certainly not an unbounded line buffer).
    use std::io::{BufRead, BufReader, Write};
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut big = "x".repeat(tweakllm::server::MAX_LINE_BYTES + 1024);
    big.push('\n');
    stream.write_all(big.as_bytes()).unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    let resp = tweakllm::util::Json::parse(&line).unwrap();
    let err = resp.get("error").unwrap().str().unwrap().to_string();
    assert!(err.contains("exceeds"), "{err}");
    stop.signal();
    drop(stream);
    let _ = join.join().unwrap();
}

#[test]
fn invalid_utf8_line_gets_structured_error_and_connection_survives() {
    use std::io::{BufRead, BufReader, Write};
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.write_all(&[0xff, 0xfe, 0xfd, b'\n']).unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = tweakllm::util::Json::parse(&line).unwrap();
    assert!(resp.get("error").unwrap().str().unwrap().contains("UTF-8"));
    // The stream stays line-synced: a well-formed follow-up still answers.
    stream.write_all(b"{\"query\": \"hello after garbage\"}\n").unwrap();
    stream.flush().unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = tweakllm::util::Json::parse(&line).unwrap();
    assert!(resp.opt("pathway").is_some(), "{}", resp.to_string());
    stop.signal();
    drop(stream);
    let _ = join.join().unwrap();
}

#[test]
fn engine_in_process_handle_works_alongside_tcp() {
    let (_engine, handle, _addr, stop, _join) = start_stack();
    let r = handle.request("direct in-process request").unwrap();
    assert!(!r.text.is_empty());
    let stats = handle.stats().unwrap();
    assert!(stats.requests >= 1);
    stop.signal();
}
