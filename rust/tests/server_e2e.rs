//! Engine + TCP server end-to-end over mock models (no artifacts needed):
//! real sockets, real engine thread, real dynamic batching.

use std::sync::atomic::Ordering;

use tweakllm::baselines::MockLlm;
use tweakllm::config::{Config, IndexKindConfig};
use tweakllm::coordinator::{Engine, EngineHandle, Router};
use tweakllm::runtime::{NativeBowEmbedder, TextEmbedder};
use tweakllm::server::{Client, Server};

fn start_stack() -> (tweakllm::coordinator::Engine, EngineHandle, String, std::sync::Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let (engine, handle) = Engine::start(|| {
        let mut cfg = Config::paper();
        cfg.index.kind = IndexKindConfig::Flat;
        cfg.exact_match_fast_path = true;
        let embedder: Box<dyn TextEmbedder> = Box::new(NativeBowEmbedder::new(128, 7));
        Ok(Router::with_models(
            embedder,
            Box::new(MockLlm::new("big")),
            Box::new(MockLlm::new("small")),
            cfg,
        ))
    })
    .expect("engine start");
    let server = Server::bind("127.0.0.1:0", handle.clone()).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_flag();
    let join = std::thread::spawn(move || server.serve());
    (engine, handle, addr, stop, join)
}

#[test]
fn query_roundtrip_over_tcp() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();

    let r1 = client.query("why is coffee good for health?").unwrap();
    assert_eq!(r1.get("pathway").unwrap().str().unwrap(), "miss");
    assert!(r1.get("text").unwrap().str().unwrap().contains("big-fresh"));

    let r2 = client.query("why is coffee great for health?").unwrap();
    assert_eq!(r2.get("pathway").unwrap().str().unwrap(), "tweak_hit");
    let sim = r2.get("similarity").unwrap().f64().unwrap();
    assert!(sim >= 0.7, "sim={sim}");

    let r3 = client.query("why is coffee good for health?").unwrap();
    assert_eq!(r3.get("pathway").unwrap().str().unwrap(), "exact_hit");

    stop.store(true, Ordering::Relaxed);
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn stats_endpoint_reports_counters() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    client.query("explain the soil of tomatoes").unwrap();
    client.query("explain the soil of tomatoes please").unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("requests").unwrap().f64().unwrap() as u64, 2);
    assert_eq!(stats.get("cache_size").unwrap().f64().unwrap() as u64, 1);
    let hits = stats.get("tweak_hits").unwrap().f64().unwrap()
        + stats.get("exact_hits").unwrap().f64().unwrap();
    assert_eq!(hits as u64, 1);
    stop.store(true, Ordering::Relaxed);
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn malformed_request_reports_error_not_crash() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut client = Client::connect(&addr).unwrap();
    let resp = client
        .roundtrip(&tweakllm::util::Json::obj_from(vec![(
            "nonsense",
            tweakllm::util::Json::num(1.0),
        )]))
        .unwrap();
    assert!(resp.opt("error").is_some());
    // server still alive afterwards
    let ok = client.query("hello there").unwrap();
    assert!(ok.opt("pathway").is_some());
    stop.store(true, Ordering::Relaxed);
    drop(client);
    let _ = join.join().unwrap();
}

#[test]
fn concurrent_clients_all_served() {
    let (_engine, _handle, addr, stop, join) = start_stack();
    let mut joins = Vec::new();
    for c in 0..4 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut served = 0;
            for i in 0..10 {
                let r = client.query(&format!("client {c} question {i} about topic {i}")).unwrap();
                assert!(r.opt("pathway").is_some(), "{}", r.to_string());
                served += 1;
            }
            served
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 40);
    stop.store(true, Ordering::Relaxed);
    let _ = join.join().unwrap();
}

#[test]
fn engine_in_process_handle_works_alongside_tcp() {
    let (_engine, handle, _addr, stop, _join) = start_stack();
    let r = handle.request("direct in-process request").unwrap();
    assert!(!r.text.is_empty());
    let stats = handle.stats().unwrap();
    assert!(stats.requests >= 1);
    stop.store(true, Ordering::Relaxed);
}
