//! Full evaluation-pipeline integration (artifact-free: native embedder):
//! every figure harness runs end to end at reduced scale and must produce
//! the paper's qualitative shape. These are the guardrails that keep the
//! benches honest.

use tweakllm::baselines::{AlbertLike, CrossEncoder};
use tweakllm::datasets::{ChatTrace, QuestionPairDataset, TraceProfile};
use tweakllm::eval::debate::{debate, default_personas, DebateConfig, VerdictCounts};
use tweakllm::eval::hit_rate;
use tweakllm::eval::precision_recall::run_at_threshold;
use tweakllm::eval::quality::QualityModel;
use tweakllm::eval::survey::{run_survey, SurveyConfig, SurveyItem};
use tweakllm::eval::Band;
use tweakllm::runtime::NativeBowEmbedder;
use tweakllm::util::Rng;

#[test]
fn fig2_shape_precision_up_recall_down() {
    let ds = QuestionPairDataset::generate(250, 3);
    let emb = NativeBowEmbedder::new(128, 5);
    let lo = run_at_threshold(&ds.pairs, &emb, Box::new(AlbertLike::default()), 0.70).unwrap();
    let hi = run_at_threshold(&ds.pairs, &emb, Box::new(AlbertLike::default()), 0.95).unwrap();
    assert!(lo.counts.precision() > 0.6, "precision@0.7 = {}", lo.counts.precision());
    assert!(hi.counts.precision() >= lo.counts.precision() - 0.05);
    assert!(hi.counts.recall() < lo.counts.recall());
}

#[test]
fn fig3_shape_satisfaction_tracks_band() {
    let mut qm = QualityModel::new(7);
    let mut items = Vec::new();
    for band in Band::ALL {
        for _ in 0..40 {
            items.push(SurveyItem {
                band,
                big: qm.big_direct(),
                tweaked: qm.small_tweaked(band.midpoint(), None),
            });
        }
    }
    let r = run_survey(&items, &SurveyConfig::default(), 7);
    // top band: tweaked >= big - small noise margin (the paper's headline)
    let top = r.satisfaction.iter().find(|(b, _, _)| *b == Band::B90).unwrap();
    assert!(top.2.rate() >= top.1.rate() - 6.0, "big={} tweaked={}", top.1.rate(), top.2.rate());
    // bands comparable everywhere (within 25 points)
    for (_, big, tweaked) in &r.satisfaction {
        assert!((big.rate() - tweaked.rate()).abs() < 25.0);
    }
}

#[test]
fn fig5_7_shape_tweaked_gains_with_band_and_beats_direct() {
    let personas = default_personas();
    let cfg = DebateConfig::default();
    let mut qm = QualityModel::new(11);
    let mut rng = Rng::new(11);
    let mut per_band_tweaked = Vec::new();
    let mut per_band_direct = Vec::new();
    for band in Band::ALL {
        let mut ct = VerdictCounts::default();
        let mut cd = VerdictCounts::default();
        for _ in 0..300 {
            let big = qm.big_direct();
            let tweaked = qm.small_tweaked(band.midpoint(), None);
            ct.push(debate(&big, &tweaked, &personas, &cfg, &mut rng).verdict);
            let direct = qm.small_direct();
            cd.push(debate(&big, &direct, &personas, &cfg, &mut rng).verdict);
        }
        per_band_tweaked.push(ct.frac_b_or_draw());
        per_band_direct.push(cd.frac_b_or_draw());
    }
    // Fig 5/7 trend: monotone in band
    assert!(per_band_tweaked[0] < per_band_tweaked[2],
        "trend: {per_band_tweaked:?}");
    // Fig 6 control: direct far below tweaked in every band
    for (t, d) in per_band_tweaked.iter().zip(&per_band_direct) {
        assert!(d + 0.1 < *t, "tweaked={t} direct={d}");
    }
    // rough magnitudes (paper: 32.9/40.1/46.1)
    assert!(per_band_tweaked[0] > 0.10 && per_band_tweaked[0] < 0.60);
    assert!(per_band_tweaked[2] > 0.30 && per_band_tweaked[2] < 0.75);
}

#[test]
fn fig8_9_shape_lmsys_above_wildchat() {
    let emb = NativeBowEmbedder::new(96, 9);
    let l = ChatTrace::generate(TraceProfile::lmsys(), 2500, 9);
    let w = ChatTrace::generate(TraceProfile::wildchat(), 2500, 9);
    let (la, lb) = l.halves();
    let (wa, wb) = w.halves();
    let lc = hit_rate::run(la, lb, &emb).unwrap();
    let wc = hit_rate::run(wa, wb, &emb).unwrap();
    assert!(lc.hit_rate_at(0.8) > wc.hit_rate_at(0.8));
    // cost ordering follows (paper: 35% vs 61%)
    assert!(lc.cost_ratio(0.8, 25.0) < wc.cost_ratio(0.8, 25.0));
}

#[test]
fn gptcache_verbatim_cannot_fix_polarity_but_tweak_can() {
    // the paper's §6 discussion: polarity-flipped hits are unsafe verbatim
    // but resolvable by tweaking — encoded as a regression test.
    let emb = NativeBowEmbedder::new(128, 13);
    let ce = AlbertLike::default();
    let good = "why is coffee good for health ?";
    let bad = "why is coffee bad for health ?";
    // bi-encoder cosine is high (the trap):
    use tweakllm::runtime::TextEmbedder;
    let eg = emb.embed(good).unwrap();
    let eb = emb.embed(bad).unwrap();
    // one content word differs out of three: lands in the cacheable zone
    assert!(tweakllm::util::dot(&eg, &eb) > 0.55);
    // the cross-encoder *usually* catches it, but the paper's point is the
    // residual risk; the quality model shows the tweak path resolves it:
    let _ = ce.score(good, bad);
    let mut qm = QualityModel::new(17);
    use tweakllm::datasets::IntentKey;
    let a = IntentKey { domain: 1, entity: 1, attribute: 1, polarity: 0, class: 0, variant: 0 };
    let b = IntentKey { polarity: 1, ..a };
    // verbatim serving of a flipped answer == relevance of the cached
    // response to the flipped query ~= intent affinity (low):
    let verbatim_rel = tweakllm::datasets::intent_affinity(&a, &b);
    assert!(verbatim_rel < 0.5);
    // tweaking regenerates: quality lands near small-direct, far above
    // serving the wrong-polarity answer
    let mut tq = 0.0;
    for _ in 0..200 {
        tq += qm.small_tweaked(0.92, Some((&a, &b))).mean();
    }
    tq /= 200.0;
    assert!(tq > verbatim_rel + 0.2, "tweaked={tq} verbatim={verbatim_rel}");
}
